"""Metrics layer: histogram percentiles vs numpy, lifecycle accounting."""

import json
import math

import numpy as np
import pytest

from repro.serve.metrics import (ServeMetrics, StreamingHistogram,
                                 VirtualClock, WallClock)


@pytest.mark.parametrize("dist", ["lognormal", "uniform", "exponential"])
@pytest.mark.parametrize("q", [50, 90, 99])
def test_percentiles_match_numpy(dist, q):
    rng = np.random.default_rng(42)
    if dist == "lognormal":
        xs = rng.lognormal(-2.0, 1.0, 20000)
    elif dist == "uniform":
        xs = rng.uniform(0.001, 2.0, 20000)
    else:
        xs = rng.exponential(0.05, 20000)
    h = StreamingHistogram()
    for x in xs:
        h.record(x)
    want = np.percentile(xs, q)
    got = h.percentile(q)
    # log-spaced buckets at 2% growth: ~2% relative resolution
    assert abs(got - want) / want < 0.03, (dist, q, got, want)


def test_exact_stats_and_extremes():
    h = StreamingHistogram()
    xs = [0.5, 1.0, 2.0, 4.0]
    for x in xs:
        h.record(x)
    assert h.count == 4
    assert h.min == 0.5 and h.max == 4.0
    assert math.isclose(h.mean, sum(xs) / 4)
    assert h.percentile(0) >= 0.5
    assert h.percentile(100) == 4.0


def test_out_of_range_values_clamped():
    h = StreamingHistogram(lo=1e-3, hi=1e3)
    h.record(1e-9)          # underflow bucket
    h.record(1e9)           # overflow bucket
    assert h.count == 2
    assert h.percentile(100) == 1e9
    s = h.summary()
    assert s["count"] == 2 and s["min"] == 1e-9 and s["max"] == 1e9


def test_empty_histogram_summary():
    s = StreamingHistogram().summary()
    assert s["count"] == 0 and s["p99"] == 0.0 and s["min"] == 0.0


def test_lifecycle_with_virtual_clock():
    clock = VirtualClock()
    m = ServeMetrics(clock, slots=2)
    m.on_submit(0, arrival=0.0)
    clock.advance(3.0)
    m.on_admit(0, prompt_len=5)
    m.on_token(0)                       # first token at t=3 -> ttft 3
    clock.advance(1.0)
    m.on_token(0)                       # tpot 1
    clock.advance(2.0)
    m.on_token(0)                       # tpot 2
    m.on_finish(0)                      # e2e 6
    m.on_step(queue_depth=4, active_slots=1)
    m.on_step(queue_depth=0, active_slots=2)

    snap = m.snapshot()
    assert snap["requests"] == {"submitted": 1, "completed": 1,
                                "backpressure_events": 0}
    assert snap["tokens"] == {"prefill": 5, "decode": 3}
    assert abs(snap["ttft"]["p50"] - 3.0) / 3.0 < 0.03
    assert snap["tpot"]["count"] == 2
    assert abs(snap["e2e"]["max"] - 6.0) < 1e-9
    assert snap["queue_depth"]["mean"] == 2.0       # (4 + 0) / 2
    assert snap["slot_utilization"] == 0.75         # (1 + 2) / (2 * 2)
    json.dumps(snap)                    # JSON-able


def test_ttft_includes_queueing_from_arrival():
    clock = VirtualClock()
    m = ServeMetrics(clock)
    clock.advance(10.0)
    m.on_submit(1, arrival=2.0)         # arrived at t=2, submitted late
    m.on_admit(1, 3)
    m.on_token(1)
    assert abs(m.ttft.max - 8.0) < 1e-9


def test_wall_clock_monotone():
    c = WallClock()
    a = c.now()
    c.advance(100.0)                    # no-op for wall clocks
    b = c.now()
    assert b >= a and b < 50.0
