"""Optimised execution paths (§Perf) must be exact vs their baselines:
absorbed MLA, shard_map expert-parallel MoE, bf16 attention probs."""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.distributed.sharding import axis_rules
from repro.models import moe as moe_mod
from repro.models.layers import attention_core, set_attention_options
from repro.models.model import Model, RunConfig


@pytest.fixture(autouse=True)
def _reset_knobs():
    yield
    moe_mod.set_moe_impl("auto")
    set_attention_options(probs_dtype="float32", block_q=512, block_k=1024)


def test_absorbed_mla_equals_nonabsorbed():
    """Decode (absorbed, latent-MQA) must match teacher forcing
    (non-absorbed reconstruction) bit-for-bit up to f32 roundoff."""
    cfg = reduced(get_config("deepseek_v2_236b"))
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0))
    m = Model(cfg, RunConfig(max_seq=32))
    p = m.init(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0,
                              cfg.vocab_size)
    full, _, _ = m.apply(p, toks)
    cache = m.cache_init(2, 32)
    pre, cache, _ = m.apply(p, toks[:, :8], cache=cache)
    errs = [float(jnp.abs(pre - full[:, :8]).max())]
    for t in range(8, 12):
        lg, cache, _ = m.apply(p, toks[:, t:t + 1], cache=cache)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert max(errs) < 2e-4


def _moe_model():
    cfg = reduced(get_config("kimi_k2_1t"))
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, num_experts=8, top_k=2, capacity_factor=8.0))
    return Model(cfg, RunConfig(max_seq=32)), cfg


def test_shardmap_moe_matches_gspmd():
    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs multiple devices (run via XLA_FLAGS host count)")
    model, cfg = _moe_model()
    params = model.init(jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                                cfg.vocab_size)
    mesh = jax.make_mesh((n // 2, 2), ("data", "model"))
    moe_mod.set_moe_impl("gspmd")
    with mesh, axis_rules(mesh):
        ref, _, _ = jax.jit(lambda p, t: model.apply(p, t))(params, tokens)
    moe_mod.set_moe_impl("shardmap")
    with mesh, axis_rules(mesh):
        got, _, _ = jax.jit(lambda p, t: model.apply(p, t))(params, tokens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-4)


def test_shardmap_moe_subprocess_multi_device():
    """Run the cross-impl check under 8 virtual devices.

    This was xfailed from PR 1 to PR 3 (max err ~8.8e-3 > 2e-4).  The
    divergence was root-caused to the *gspmd* path, not shard_map: its
    combine gathered expert outputs through an (E*capacity+1)-row
    concatenate (a trailing trash row for dropped tokens), and GSPMD
    mispartitions that odd-sized computed-index gather under a
    model-sharded mesh — per-token routed contributions came back
    wrong/zeroed while the shard_map path was bit-exact against the
    unsharded oracle.  apply_moe now keeps the dispatch buffer exactly
    E*capacity rows and masks dropped slots explicitly, which is
    bit-exact under partitioning, so the two impls agree to f32
    roundoff and the xfail is gone.  (Capacity drop ordering and psum
    dtype — the original suspects — were ruled out: routing, keep masks
    and the aux loss matched exactly throughout.)"""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = (
        "import os;"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8';"
        "import dataclasses, jax, jax.numpy as jnp, numpy as np;"
        "from repro.configs.base import get_config, reduced;"
        "from repro.models.model import Model, RunConfig;"
        "from repro.models import moe as moe_mod;"
        "from repro.distributed.sharding import axis_rules;"
        "cfg = reduced(get_config('kimi_k2_1t'));"
        "cfg = dataclasses.replace(cfg, moe=dataclasses.replace("
        "cfg.moe, num_experts=8, top_k=2, capacity_factor=8.0));"
        "m = Model(cfg, RunConfig(max_seq=32));"
        "p = m.init(jax.random.PRNGKey(1));"
        "t = jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, "
        "cfg.vocab_size);"
        "mesh = jax.make_mesh((2, 4), ('data', 'model'));"
        "moe_mod.set_moe_impl('gspmd');\n"
        "with mesh, axis_rules(mesh):\n"
        "    a, _, _ = jax.jit(lambda p, t: m.apply(p, t))(p, t)\n"
        "moe_mod.set_moe_impl('shardmap')\n"
        "with mesh, axis_rules(mesh):\n"
        "    b, _, _ = jax.jit(lambda p, t: m.apply(p, t))(p, t)\n"
        "err = float(jnp.abs(a - b).max());"
        "assert err < 2e-4, err;"
        "print('ok', err)")
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600, env=env, cwd=repo)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "ok" in r.stdout


def test_bf16_probs_error_bounded():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 4096, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 4096, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 4096, 2, 32)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(4096)[None], (1, 4096))
    set_attention_options(probs_dtype="float32")
    a = attention_core(q, k, v, pos, pos, None, True, None)
    set_attention_options(probs_dtype="bfloat16")
    b = attention_core(q, k, v, pos, pos, None, True, None)
    err = float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
    assert err < 2e-2, err


def test_pallas_decode_backend_matches_xla():
    """The model's serving fast path (pallas decode-attention kernel)
    must produce bit-identical logits to the XLA path."""
    cfg = reduced(get_config("qwen2_7b"))
    m_x = Model(cfg, RunConfig(max_seq=32, backend="xla"))
    m_p = Model(cfg, RunConfig(max_seq=32, backend="pallas"))
    params = m_x.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    cache_x = m_x.cache_init(2, 32)
    cache_p = m_p.cache_init(2, 32)
    _, cache_x, _ = m_x.apply(params, toks[:, :8], cache=cache_x)
    _, cache_p, _ = m_p.apply(params, toks[:, :8], cache=cache_p)
    for t in range(8, 12):
        lx, cache_x, _ = m_x.apply(params, toks[:, t:t + 1], cache=cache_x)
        lp, cache_p, _ = m_p.apply(params, toks[:, t:t + 1], cache=cache_p)
        assert float(jnp.abs(lx - lp).max()) < 2e-4
