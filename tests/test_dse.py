"""DSE subsystem: schedule-program search, Pareto frontier, candidate
cache, co-sim validation, and the pass/CLI wiring (PR 4 tentpole)."""

import dataclasses
import io
import json
import os

import numpy as np
import pytest

from repro.core import dse, hw_ir, reproc
from repro.core.dse import (DsePoint, ResourceBudget, dominates,
                            enumerate_points, explore, pareto_frontier,
                            vectorize_legal)
from repro.core.machine_model import TPU_V5E
from repro.core.passes import PassError, PassManager
from repro.core.pipeline import compile_gemm
from repro.core.reproc import quickstart_gemm


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("STAGECC_DSE_CACHE", str(tmp_path / "dse-cache"))


def _gemm(s, epilogue="none"):
    return quickstart_gemm(s, s, s, epilogue=epilogue)


# --------------------------------------------------------------------------
# enumeration
# --------------------------------------------------------------------------


def test_space_contains_paper_points_and_knobs():
    pts = enumerate_points(_gemm(8))
    fams = {p.family for p in pts}
    assert {"nested", "inner_flattened", "split_unroll", "stream_outer",
            "tpu_mxu", "tpu_mxu_kgrid", "vmem_acc"} <= fams
    # every point is a replayable pipeline: parse them all
    for p in pts:
        PassManager.parse(p.pipeline)
        if p.hw_pipeline:
            PassManager.parse(p.hw_pipeline)


def test_canonical_dedupe_shrinks_and_logs():
    """grid{vars=2} vs grid{vars=3} at full-dim tiles are the same design
    (the extra grid loop has extent 1): the canonical-form dedupe drops
    one, records the (eliminated, kept) pair, and the table names it."""
    g = _gemm(8)
    points = enumerate_points(g)
    kept, dropped = dse.dedupe_points(g, points)
    assert len(kept) + len(dropped) == len(points)
    assert dropped, "full-dim kgrid point should dedupe against tpu_mxu"
    fams = {(gone.family, k.family) for gone, k in dropped}
    assert ("tpu_mxu_kgrid", "tpu_mxu") in fams
    res = explore(g)
    assert [p.spec for p, _ in res.deduped] == \
        [p.spec for p, _ in dropped]
    assert len(res.candidates) == len(kept)
    table = res.table()
    assert f"canonical-form dedupe eliminated {len(dropped)}" in table
    for gone, k in dropped:
        assert gone.spec in table and k.spec in table


def test_canonical_key_tolerates_failing_points():
    """A point whose pipeline fails must be kept (so explore records the
    real error), not silently deduped away."""
    g = _gemm(8)
    bogus = DsePoint("broken", "lower,split{var=nope,factor=2}")
    assert dse.canonical_key(g, bogus) is None
    kept, dropped = dse.dedupe_points(g, [bogus, bogus])
    assert kept == [bogus, bogus] and not dropped


def test_vectorize_legality_guards_reductions():
    """GEMM's K loop accumulates into a K-invariant tile: not SIMD-legal
    (and neither are i/j, which share the accumulator); the epilogue's
    elementwise loops are."""
    pure = enumerate_points(_gemm(8))
    assert not any(p.family == "simd" for p in pure)
    withep = enumerate_points(_gemm(8, epilogue="bias_relu"))
    simd = [p for p in withep if p.family == "simd"]
    assert simd, "elementwise epilogue loops must yield simd points"
    # and the generated vectorize pipelines actually run + verify
    g = _gemm(8, epilogue="bias_relu")
    for p in simd:
        PassManager.parse(p.pipeline).run(g)
    # direct check on the lowered kernel
    k = dse._lower_nested(_gemm(8))
    loops = {l.var.name: l for l in k.loops()}
    assert not vectorize_legal(k, loops["k3"])
    assert not vectorize_legal(k, loops["j2"])


# --------------------------------------------------------------------------
# frontier + validation (the acceptance contract, fast size)
# --------------------------------------------------------------------------


def _assert_paper_points(res):
    """Both paper points are priced; `inner_flattened` stays on the
    frontier.  `nested` may legitimately be *dominated* now — but only
    by a resource-sharing point (PR 9's `set-sharing` serializes the
    flattened datapath down to nested's area at fewer cycles); anything
    else knocking it off is a regression."""
    fams = {c.point.family for c in res.frontier}
    assert "inner_flattened" in fams
    nested = next(c for c in res.candidates if c.point.family == "nested")
    if not nested.on_frontier:
        sharers = [c for c in res.candidates
                   if c.point.family in ("shared", "flat_serialized")
                   and dominates((c.cycles.total, c.area),
                                 (nested.cycles.total, nested.area))]
        assert sharers, f"nested dominated by a non-sharing family: {fams}"
    return fams


def test_frontier_8cube_contains_paper_points_plus_new():
    res = explore(_gemm(8), validate_top=64)
    fams = _assert_paper_points(res)
    new = fams - {"nested", "inner_flattened"}
    assert len(new) >= 3, f"expected >=3 new non-dominated families: {fams}"
    # every frontier point co-simulates: exact numerics, modeled cycles
    assert len(res.validations) == len(res.frontier)
    for v in res.validations:
        assert v.ok, v.detail
        assert v.max_abs_err <= 1e-5
        assert v.cycle_dev_pct <= 10.0
    assert not res.errors


@pytest.mark.slow
def test_frontier_32cube_full_acceptance():
    """PR-4 acceptance: the 32^3 GEMM frontier holds both paper points
    and >=3 strictly non-dominated new schedules; every frontier point
    co-simulates within 1e-5 of the numpy oracle and +-10% of its
    modeled cycles."""
    res = explore(_gemm(32), validate_top=64)
    fams = _assert_paper_points(res)
    assert len(fams - {"nested", "inner_flattened"}) >= 3
    assert len(res.validations) == len(res.frontier)
    for v in res.validations:
        assert v.ok, v.detail
        assert v.max_abs_err <= 1e-5
        assert v.cycle_dev_pct <= 10.0


def test_frontier_is_strictly_non_dominated():
    res = explore(_gemm(8))
    front = res.frontier
    for a in front:
        for b in front:
            assert not dominates(a.key, b.key) or a.key == b.key
    # dominated candidates really are dominated by someone on the frontier
    for c in res.candidates:
        if c.feasible and not c.on_frontier:
            assert any(dominates(f.key, c.key) for f in front)


def test_pareto_frontier_unit():
    def cand(cycles, area, feasible=True):
        c = dse.DseCandidate(
            point=DsePoint("f", "lower"), cycles=None, resources=None,
            area=area, dbuf_bytes=0, feasible=feasible)
        c.cycles = dataclasses.make_dataclass("C", ["total"])(cycles)
        return c

    a, b, c, d = cand(10, 10), cand(10, 5), cand(5, 20), cand(3, 30, False)
    front = pareto_frontier([a, b, c, d])
    assert b in front and c in front
    assert a not in front            # dominated by b
    assert d not in front            # infeasible


def test_budget_marks_infeasible():
    tight = ResourceBudget(max_lanes=1, max_vmem_bytes=1 << 20,
                           max_reg_bits=1 << 20)
    res = explore(_gemm(8), budget=tight)
    mxu = [c for c in res.candidates if c.point.family == "tpu_mxu"]
    assert mxu and all(not c.feasible for c in mxu)
    assert all(c.resources.compute_lanes <= 1 for c in res.frontier)


# --------------------------------------------------------------------------
# the on-disk candidate cache
# --------------------------------------------------------------------------


def test_cache_hits_on_second_run(tmp_path):
    cdir = str(tmp_path / "cache")
    r1 = explore(_gemm(8), cache_dir=cdir)
    assert not any(c.cached for c in r1.candidates)
    r2 = explore(_gemm(8), cache_dir=cdir)
    assert all(c.cached for c in r2.candidates)
    by_spec = {c.point.spec: c for c in r1.candidates}
    for c in r2.candidates:
        o = by_spec[c.point.spec]
        assert (c.cycles, c.resources, c.area, c.feasible) == \
            (o.cycles, o.resources, o.area, o.feasible)


def test_warm_cache_compiles_nothing(tmp_path, monkeypatch):
    """The canonical dedupe key rides in the on-disk cache (deduped
    points store a key-only entry), so a warm explore never rebuilds a
    single point — dedupe included."""
    cdir = str(tmp_path / "cache")
    explore(_gemm(8), cache_dir=cdir)
    calls = []
    orig = dse.build_point
    monkeypatch.setattr(dse, "build_point",
                        lambda *a, **k: (calls.append(a[1].spec),
                                         orig(*a, **k))[1])
    r = explore(_gemm(8), cache_dir=cdir)
    assert calls == [], "warm explore must not recompile any point"
    assert r.deduped, "dedupe must still be reported from the cache"
    assert all(c.cached for c in r.candidates)


def test_cache_keyed_by_machine_and_graph(tmp_path):
    cdir = str(tmp_path / "cache")
    explore(_gemm(8), cache_dir=cdir)
    other = dataclasses.replace(TPU_V5E, name="other",
                                seq_loop_overhead_cycles=1.0)
    r = explore(_gemm(8), machine=other, cache_dir=cdir)
    assert not any(c.cached for c in r.candidates), \
        "a different machine must not reuse cached pricings"
    r3 = explore(_gemm(16), cache_dir=cdir)
    assert not any(c.cached for c in r3.candidates)


def test_cache_survives_corruption(tmp_path):
    cdir = str(tmp_path / "cache")
    explore(_gemm(8), cache_dir=cdir)
    # alternate syntactic corruption with valid-JSON-wrong-shape entries
    for j, fn in enumerate(sorted(os.listdir(cdir))):
        with open(os.path.join(cdir, fn), "w") as f:
            f.write("{not json" if j % 2 else "[1, 2]")
    r = explore(_gemm(8), cache_dir=cdir)
    assert not any(c.cached for c in r.candidates)
    kept, _ = dse.dedupe_points(_gemm(8), enumerate_points(_gemm(8)))
    assert len(r.candidates) == len(kept)


# --------------------------------------------------------------------------
# wiring: passes, CompiledKernel.explore, reproc CLI
# --------------------------------------------------------------------------


def test_dse_pass_returns_winning_kernel():
    out = PassManager.parse("dse").run(_gemm(16))
    kern = out.artifact
    res = explore(_gemm(16))
    want = PassManager.parse(res.best().point.pipeline) \
        .run(_gemm(16)).artifact
    from repro.core import ir_text
    assert ir_text.print_ir(kern) == ir_text.print_ir(want)


def test_set_sequencer_pass_round_trip_and_errors():
    k = PassManager.parse("lower").run(_gemm(4)).artifact
    mod = hw_ir.lower_to_hw(k)
    outer = [l for l in mod.loops()][0]
    assert outer.kind == "fsm"
    hw_ir.set_sequencer(mod, outer.counter, "stream")
    assert outer.kind == "stream"
    hw_ir.set_sequencer(mod, outer.counter, "fsm")
    assert outer.kind == "fsm"
    with pytest.raises(ValueError, match="spatial"):
        hw_ir.set_sequencer(mod, outer.counter, "unroll")
    with pytest.raises(KeyError, match="nope"):
        hw_ir.set_sequencer(mod, "nope", "stream")
    # and through the pass manager, spatial loops are rejected
    from repro.core import schedule as sched
    k2 = PassManager.parse("lower,flatten-inner").run(_gemm(4)).artifact
    mod2 = hw_ir.lower_to_hw(k2)
    spatial = [l for l in mod2.loops() if l.kind == "unroll"][0]
    with pytest.raises(PassError, match="temporal"):
        PassManager.parse(
            f"set-sequencer{{counter={spatial.counter},kind=stream}}"
        ).run(mod2)


def test_set_space_pass_errors():
    with pytest.raises(PassError, match="unknown space"):
        PassManager.parse("lower,set-space{buffer=acc4,space=sram}") \
            .run(_gemm(8))
    with pytest.raises(PassError, match="hbm"):
        PassManager.parse("lower,set-space{buffer=acc4,space=hbm}") \
            .run(_gemm(8))


def test_compiled_kernel_explore():
    ck = compile_gemm(8, 8, 8, want_jax=False, want_pallas=False)
    res = ck.explore(validate_top=1)
    assert res.frontier and res.validations[0].ok
    assert res.machine is ck.machine


def test_stream_knob_numerics_preserved():
    """set-sequencer changes scheduling, never semantics: the re-
    sequenced module still co-simulates exactly."""
    res = explore(_gemm(8), validate_top=64)
    streamed = [v for v in res.validations
                if v.point.family in ("stream_outer", "flat_stream")]
    assert streamed, "a stream-knob point should reach the frontier"
    assert all(v.ok and v.max_abs_err <= 1e-5 for v in streamed)


def test_reproc_dse_cli(tmp_path):
    csv = tmp_path / "pareto.csv"
    buf = io.StringIO()
    rc = reproc.main(["--gemm", "8x8x8", "--epilogue", "none",
                      "--dse=2", "--pareto-csv", str(csv)], out=buf)
    assert rc == 0
    text = buf.getvalue()
    assert "Pareto frontier" in text and "cosim" in text
    rows = csv.read_text().strip().splitlines()
    assert rows[0].startswith("family,spec,cycles")
    kept, dropped = dse.dedupe_points(_gemm(8), enumerate_points(_gemm(8)))
    assert len(rows) == 1 + len(kept)
    # the shrinkage is logged, never silent
    assert f"canonical-form dedupe eliminated {len(dropped)}" in text
    # flag validation
    assert reproc.main(["--pareto-csv", "x.csv"], out=io.StringIO()) == 2
    assert reproc.main(["--dse", "--pipeline", "lower"],
                       out=io.StringIO()) == 2


def test_dse_csv_roundtrips_fields():
    res = explore(_gemm(8), validate_top=1)
    rows = res.to_csv().strip().splitlines()
    hdr = rows[0].split(",")
    for row in rows[1:]:
        # spec is quoted (it contains commas); strip it before splitting
        assert row.count('"') == 2
        pre, spec, post = row.split('"')
        assert len(pre.split(",")[:-1]) + 1 + len(post.split(",")[1:]) \
            == len(hdr)
