"""Per-arch smoke tests: reduced config, one forward + one train step on
CPU, asserting output shapes and finiteness (assignment requirement)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCHS, get_config, reduced
from repro.models.model import Model, RunConfig
from repro.optim import schedule as sched
from repro.optim.optimizer import adamw
from repro.train.step import TrainConfig, init_state, make_train_step


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (B, S + 1)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks[:, :-1]),
             "labels": jnp.asarray(toks[:, 1:]),
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.frontend == "image_patches":
        batch["extra_embeds"] = 0.1 * jnp.ones(
            (B, cfg.frontend_len, cfg.d_model))
    if cfg.frontend == "audio_frames":
        batch["extra_embeds"] = 0.1 * jnp.ones(
            (B, cfg.encoder.context, cfg.encoder.d_model or cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg, RunConfig(max_seq=32))
    B, S = 2, 16
    batch = _batch(cfg, B, S)

    params = model.init(jax.random.PRNGKey(0))
    logits, _, aux = model.apply(params, batch["tokens"],
                                 extra_embeds=batch.get("extra_embeds"))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    opt = adamw(sched.make("cosine", peak=1e-3, warmup_steps=2,
                           total_steps=10))
    step = jax.jit(make_train_step(model, opt, TrainConfig()))
    state = init_state(model, opt, jax.random.PRNGKey(0))
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: non-finite loss"
    assert float(metrics["loss"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_exactness(arch):
    """The full (assignment-exact) config numbers must survive round-trip."""
    cfg = get_config(arch)
    expected = {
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256_000),
        "qwen1_5_32b": (64, 5120, 40, 40, 27392, 152_064),
        "gemma3_4b": (34, 2560, 8, 4, 10240, 262_144),
        "minicpm_2b": (40, 2304, 36, 36, 5760, 122_753),
        "qwen2_7b": (28, 3584, 28, 4, 18944, 152_064),
        "mamba2_130m": (24, 768, 24, 24, 0, 50_280),
        "deepseek_v2_236b": (60, 5120, 128, 128, 1536, 102_400),
        "kimi_k2_1t": (61, 7168, 64, 8, 2048, 163_840),
        "pixtral_12b": (40, 5120, 32, 8, 14336, 131_072),
        "whisper_base": (6, 512, 8, 8, 2048, 51_865),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, f"{arch}: config drift {got} != {expected}"


def test_param_count_magnitudes():
    """Analytic param counts land in the advertised ballparks."""
    assert 1.5e9 < get_config("recurrentgemma_2b").param_count() < 4e9
    assert 25e9 < get_config("qwen1_5_32b").param_count() < 40e9
    assert 6e9 < get_config("qwen2_7b").param_count() < 9e9
    assert 100e6 < get_config("mamba2_130m").param_count() < 200e6
    assert 180e9 < get_config("deepseek_v2_236b").param_count() < 280e9
    assert 0.8e12 < get_config("kimi_k2_1t").param_count() < 1.3e12
    assert 10e9 < get_config("pixtral_12b").param_count() < 15e9
    # MoE active params
    assert get_config("kimi_k2_1t").active_param_count() < 50e9
    assert get_config("deepseek_v2_236b").active_param_count() < 30e9


def test_reduced_param_count_matches_tree():
    for arch in ("qwen2_7b", "deepseek_v2_236b", "mamba2_130m"):
        cfg = reduced(get_config(arch))
        model = Model(cfg, RunConfig(max_seq=32))
        params = model.init(jax.random.PRNGKey(0))
        n_tree = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        assert n_tree == model.param_count()


def test_moe_capacity_drops_are_bounded():
    """With cf=1.0 and random routing some tokens drop; the layer output
    must stay finite and close to the residual for dropped tokens."""
    cfg = reduced(get_config("deepseek_v2_236b"))
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=0.5))
    model = Model(cfg, RunConfig(max_seq=32))
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 16)
    logits, _, aux = model.apply(params, batch["tokens"])
    assert bool(jnp.isfinite(logits).all())
    assert float(aux) > 0
