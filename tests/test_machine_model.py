"""Cycle/resource model tests — the TABLE I / Fig. 3 reproduction gates."""

import pytest

from repro.core.pipeline import compile_gemm

PAPER_TABLE1 = {          # size: (nested, inner-flattened) cycles, 1ns/cycle
    4: (1_498, 1_114), 8: (10_762, 7_946), 16: (81_802, 60_298),
    32: (867_594, 470_282), 64: (5_042_698, 3_527_115),
    128: (38_324_504, 26_806_047),
}


def _cycles(size, sched):
    ck = compile_gemm(size, size, size, schedule=sched,
                      want_jax=False, want_pallas=False)
    return ck.cycles.total, ck.resources


@pytest.mark.parametrize("size", sorted(PAPER_TABLE1))
def test_table1_flattened_faster(size):
    n, _ = _cycles(size, "nested")
    f, _ = _cycles(size, "inner_flattened")
    assert f < n, "flattened must consume fewer cycles (TABLE I)"


@pytest.mark.parametrize("size", [4, 8, 16, 64, 128])
def test_table1_ratio_band(size):
    """Model ratio must sit in the paper's observed band (1.3-1.5).
    (The paper's 32x32 nested entry is a self-inconsistent outlier —
    1.85x while every other size steps ~8x; excluded, see EXPERIMENTS.md.)
    """
    n, _ = _cycles(size, "nested")
    f, _ = _cycles(size, "inner_flattened")
    assert 1.25 <= n / f <= 1.55


@pytest.mark.parametrize("size", [64, 128])
def test_table1_absolute_calibration(size):
    """Within 15% absolute of the paper's cycle counts at large sizes."""
    n, _ = _cycles(size, "nested")
    f, _ = _cycles(size, "inner_flattened")
    pn, pf = PAPER_TABLE1[size]
    assert abs(n - pn) / pn < 0.15
    assert abs(f - pf) / pf < 0.15


def test_fig3_nested_resources_constant():
    lanes = [_cycles(s, "nested")[1].compute_lanes for s in (8, 32, 128)]
    assert lanes[0] == lanes[1] == lanes[2] == 1, \
        "nested = time-division multiplexing of one datapath (Fig. 3a)"


def test_fig3_flattened_resources_proportional():
    lanes = [_cycles(s, "inner_flattened")[1].compute_lanes
             for s in (8, 32, 128)]
    assert lanes == [8, 32, 128], \
        "flattened hardware grows with matrix size (Fig. 3b)"


def test_tpu_schedule_dominates_scalar():
    """Beyond-paper: the MXU schedule must beat both scalar schedules by
    orders of magnitude (the point of adapting the pipeline to TPU)."""
    n, _ = _cycles(128, "nested")
    ck = compile_gemm(128, 128, 128, schedule="tpu_mxu_kgrid",
                      want_jax=False, want_pallas=False)
    assert ck.cycles.total * 100 < n


def test_cycle_report_components_sum():
    ck = compile_gemm(16, 16, 16, schedule="nested",
                      want_jax=False, want_pallas=False)
    c = ck.cycles
    assert abs(c.total - (c.compute + c.memory + c.control)) <= 2
