"""Decode-vs-teacher-forcing logits consistency for every arch family —
the serving-correctness gate (KV caches, recurrent states, cross-attn
caches, compressed MLA caches all exercised)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCHS, get_config, reduced
from repro.models.model import Model, RunConfig

PREFILL, DECODE, MAXLEN = 8, 4, 32


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = reduced(get_config(arch))
    if cfg.moe is not None:
        # exactness requires no capacity drops (see test_models_smoke for
        # the dropping behaviour itself)
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
    model = Model(cfg, RunConfig(max_seq=MAXLEN))
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, PREFILL + DECODE
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    ee = None
    if cfg.frontend == "image_patches":
        ee = 0.1 * jnp.ones((B, cfg.frontend_len, cfg.d_model))
    if cfg.frontend == "audio_frames":
        ee = 0.1 * jnp.ones((B, cfg.encoder.context,
                             cfg.encoder.d_model or cfg.d_model))

    full, _, _ = model.apply(params, tokens, extra_embeds=ee)
    cache = model.cache_init(B, MAXLEN)
    pre, cache, _ = model.apply(params, tokens[:, :PREFILL],
                                extra_embeds=ee, cache=cache)
    errs = [float(jnp.abs(pre - full[:, :PREFILL]).max())]
    for t in range(PREFILL, S):
        lg, cache, _ = model.apply(params, tokens[:, t:t + 1], cache=cache)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert max(errs) < 2e-4, f"{arch}: decode drift {errs}"


def test_cache_len_tracks():
    cfg = reduced(get_config("qwen2_7b"))
    model = Model(cfg, RunConfig(max_seq=MAXLEN))
    params = model.init(jax.random.PRNGKey(0))
    cache = model.cache_init(1, MAXLEN)
    assert int(cache["len"]) == 0
    tok = jnp.zeros((1, 5), jnp.int32)
    _, cache, _ = model.apply(params, tok, cache=cache)
    assert int(cache["len"]) == 5
    _, cache, _ = model.apply(params, tok[:, :1], cache=cache)
    assert int(cache["len"]) == 6
