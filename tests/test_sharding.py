"""Logical-axis resolution, divisibility fallback, data pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.data.pipeline import DataConfig, Pipeline
from repro.distributed.sharding import (axis_rules, pspec_for, shard,
                                        sharding_for, tree_shardings)


def _mesh():
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def test_pspec_basic():
    mesh = _mesh()
    n = mesh.shape["data"]
    spec = pspec_for(("batch", None), (n * 2, 7), mesh)
    assert spec == P(("data",)) or spec == P("data")


def test_divisibility_fallback():
    mesh = _mesh()
    n = mesh.shape["data"]
    if n == 1:
        pytest.skip("needs >1 device to exercise fallback")
    # dim not divisible by the data axis -> replicated
    spec = pspec_for(("batch",), (n + 1,), mesh)
    assert spec == P()


def test_pod_data_prefix_fallback():
    """A composed ("pod","data") rule degrades to a prefix that divides."""
    import os
    mesh = _mesh()
    rules = {"batch": ("data", "model")}
    spec = pspec_for(("batch",), (mesh.shape["data"],), mesh, rules)
    # full product may not divide; the prefix ("data",) must
    assert spec in (P("data"), P(("data", "model")), P(("data",)))


def test_no_axis_reuse():
    mesh = _mesh()
    rules = {"a": ("data",), "b": ("data",)}
    spec = pspec_for(("a", "b"), (mesh.shape["data"],
                                  mesh.shape["data"]), mesh, rules)
    used = [s for s in spec if s is not None]
    assert len(used) <= 1, f"mesh axis reused: {spec}"


def test_shard_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = shard(x, "batch", None)
    assert y.shape == x.shape


def test_tree_shardings_structure():
    mesh = _mesh()
    axes = {"w": "batch -", "b": "-"}
    shapes = {"w": jax.ShapeDtypeStruct((8, 2), jnp.float32),
              "b": jax.ShapeDtypeStruct((2,), jnp.float32)}
    sh = tree_shardings(axes, shapes, mesh)
    assert set(sh) == {"w", "b"}


def test_rank_mismatch_raises():
    mesh = _mesh()
    with pytest.raises(ValueError):
        sharding_for("batch -", (4,), mesh)


# ---- data pipeline -----------------------------------------------------------


def test_pipeline_deterministic():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=5)
    a = Pipeline(cfg).batch(3)
    b = Pipeline(cfg).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_pipeline_steps_differ():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=5)
    p = Pipeline(cfg)
    assert not np.array_equal(p.batch(0)["tokens"], p.batch(1)["tokens"])


def test_pipeline_shards_differ_and_split():
    base = dict(vocab_size=100, seq_len=16, global_batch=8, seed=5)
    s0 = Pipeline(DataConfig(**base, num_shards=2, shard_id=0)).batch(0)
    s1 = Pipeline(DataConfig(**base, num_shards=2, shard_id=1)).batch(0)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_labels_shifted():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2, seed=0)
    b = Pipeline(cfg).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


@settings(max_examples=10, deadline=None)
@given(vocab=st.integers(10, 1000), step=st.integers(0, 1000))
def test_pipeline_tokens_in_range(vocab, step):
    cfg = DataConfig(vocab_size=vocab, seq_len=8, global_batch=2, seed=1)
    b = Pipeline(cfg).batch(step)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < vocab
