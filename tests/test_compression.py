"""Gradient compression: quantisation error bounds + error feedback."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.distributed.compression import (dequantize_int8,
                                           error_feedback_update,
                                           make_compressed_allreduce,
                                           quantize_int8)


@settings(max_examples=25, deadline=None)
@given(scale=st.floats(1e-3, 1e3), seed=st.integers(0, 100))
def test_quantize_error_bound(scale, seed):
    """|x - deq(q(x))| <= max|x| / 127 / 2 elementwise (half-step)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(64) * scale, jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    bound = jnp.max(jnp.abs(x)) / 127.0 * 0.5 + 1e-9
    assert float(err.max()) <= float(bound) * 1.001


def test_quantize_preserves_sign_and_zero():
    x = jnp.asarray([0.0, 1.0, -1.0, 0.5])
    q, s = quantize_int8(x)
    d = dequantize_int8(q, s)
    assert float(d[0]) == 0.0
    assert float(d[1]) > 0 and float(d[2]) < 0


def test_error_feedback_accumulates_unquantized_residual():
    g = {"w": jnp.asarray([1.0, 0.001, -0.002])}
    r = {"w": jnp.zeros(3)}
    gq, r2 = error_feedback_update(g, r)
    # residual + quantised must reconstruct g exactly
    np.testing.assert_allclose(np.asarray(gq["w"] + r2["w"]),
                               np.asarray(g["w"]), rtol=1e-6)


def test_error_feedback_converges_in_expectation():
    """Sum over steps of EF-compressed grads tracks the true sum."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(16)
    sent_sum = np.zeros(16)
    r = {"w": jnp.zeros(16)}
    for i in range(50):
        g = {"w": jnp.asarray(rng.standard_normal(16) * 0.01, jnp.float32)}
        true_sum += np.asarray(g["w"])
        gq, r = error_feedback_update(g, r)
        sent_sum += np.asarray(gq["w"])
    # drift bounded by one quantisation residual, not growing with steps
    drift = np.abs(true_sum - sent_sum).max()
    assert drift <= float(jnp.abs(r["w"]).max()) + 1e-6


def test_compressed_allreduce_mean():
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    reduce_fn = make_compressed_allreduce(mesh, "data")
    x = jnp.arange(n * 4, dtype=jnp.float32).reshape(n, 4)
    out = reduce_fn({"g": x})["g"]
    want = np.tile(np.asarray(x).reshape(n, 4).mean(0), (n, 1))
    np.testing.assert_allclose(np.asarray(out), want, rtol=0.02, atol=0.05)
