# Convenience targets; everything runs in place with PYTHONPATH=src.

PY := PYTHONPATH=src python

.PHONY: test docs docs-check bench-check

test:
	$(PY) -m pytest -x -q

# regenerate the generated docs (docs/PASSES.md from the pass registry,
# docs/LOWERING.md, docs/DSE.md, docs/REWRITE.md, docs/RAISING.md and
# docs/SERVING.md from live output)
docs:
	$(PY) -m repro.core.reproc --list-passes --markdown > docs/PASSES.md
	$(PY) scripts/gen_lowering_md.py > docs/LOWERING.md
	$(PY) scripts/gen_dse_md.py > docs/DSE.md
	$(PY) scripts/gen_rewrite_md.py > docs/REWRITE.md
	$(PY) scripts/gen_raising_md.py > docs/RAISING.md
	$(PY) scripts/gen_serving_md.py > docs/SERVING.md
	$(PY) scripts/gen_sharing_md.py > docs/SHARING.md
	$(PY) scripts/gen_fabric_md.py > docs/FABRIC.md

# CI gate: every committed BENCH_*.json must pass its schema's checker
bench-check:
	$(PY) scripts/check_bench.py

# CI gate: fail if any generated doc drifts from compiler output
docs-check:
	$(PY) -m repro.core.reproc --list-passes --markdown > /tmp/PASSES.md.gen
	diff -u docs/PASSES.md /tmp/PASSES.md.gen
	$(PY) scripts/gen_lowering_md.py > /tmp/LOWERING.md.gen
	diff -u docs/LOWERING.md /tmp/LOWERING.md.gen
	$(PY) scripts/gen_dse_md.py > /tmp/DSE.md.gen
	diff -u docs/DSE.md /tmp/DSE.md.gen
	$(PY) scripts/gen_rewrite_md.py > /tmp/REWRITE.md.gen
	diff -u docs/REWRITE.md /tmp/REWRITE.md.gen
	$(PY) scripts/gen_raising_md.py > /tmp/RAISING.md.gen
	diff -u docs/RAISING.md /tmp/RAISING.md.gen
	$(PY) scripts/gen_serving_md.py > /tmp/SERVING.md.gen
	diff -u docs/SERVING.md /tmp/SERVING.md.gen
	$(PY) scripts/gen_sharing_md.py > /tmp/SHARING.md.gen
	diff -u docs/SHARING.md /tmp/SHARING.md.gen
	$(PY) scripts/gen_fabric_md.py > /tmp/FABRIC.md.gen
	diff -u docs/FABRIC.md /tmp/FABRIC.md.gen
