"""Serving-under-load benchmark — the recorded perf trajectory's first entry.

Drives the batched continuous engine (``repro.serve.continuous``) with a
deterministic mixed prefill/decode workload from ``repro.serve.loadgen``
(Poisson or bursty arrivals, mixed prompt/output lengths, replayable
seed), records TTFT/TPOT/e2e latency and queue depth through
``repro.serve.metrics``, and writes ``BENCH_serve.json``: tokens/sec,
p50/p90/p99 TTFT and TPOT, slot utilization and requests completed per
config — so every future PR shows measured serving deltas instead of
claims.

The per-block compiler bridge (``repro.serve.compiled``) runs first and
its plan is embedded per entry: which forward-pass blocks of the serving
model compiled through the PassManager stack under autotuned schedules
(validated against the traced reference) and which fell back to plain
jit, with reasons.

  PYTHONPATH=src python benchmarks/serve_bench.py                 # 2 configs
  PYTHONPATH=src python benchmarks/serve_bench.py --smoke         # CI seconds
  PYTHONPATH=src python benchmarks/serve_bench.py --clock virtual # replayable
  PYTHONPATH=src python benchmarks/serve_bench.py --mesh model=2  # sharded
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional

import numpy as np

REQUIRED_METRIC_KEYS = ("tokens_per_s", "ttft", "tpot", "e2e",
                        "queue_depth", "slot_utilization", "requests")
REQUIRED_PCTL_KEYS = ("p50", "p90", "p99")


def parse_mesh(spec: Optional[str]):
    """"data=2,model=2" -> an active jax mesh, or None."""
    if not spec:
        return None
    import jax
    axes, sizes = [], []
    for part in spec.split(","):
        name, _, n = part.partition("=")
        axes.append(name.strip())
        sizes.append(int(n))
    need = int(np.prod(sizes))
    if len(jax.devices()) < need:
        raise SystemExit(
            f"mesh {spec} needs {need} devices, only {len(jax.devices())} "
            f"visible; set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{need}")
    return jax.make_mesh(tuple(sizes), tuple(axes))


def run_config(name: str, *, slots: int, requests: int, rate: float,
               process: str, seed: int, clock_kind: str,
               queue_limit: Optional[int], prompt_hi: int, out_hi: int,
               with_plan: bool, mesh=None, max_len: int = 64) -> Dict:
    import jax
    from repro.configs.base import get_config, reduced
    from repro.distributed import sharding
    from repro.models.model import Model, RunConfig
    from repro.serve import loadgen
    from repro.serve.continuous import ContinuousEngine, Request
    from repro.serve.metrics import ServeMetrics, VirtualClock, WallClock

    cfg = reduced(get_config(name))
    model = Model(cfg, RunConfig(max_seq=max_len))
    params = model.init(jax.random.PRNGKey(seed))

    plan = None
    if with_plan:
        from repro.serve.compiled import plan_blocks
        plan = plan_blocks(name, seed=seed)

    load = loadgen.LoadConfig(
        num_requests=requests, vocab_size=cfg.vocab_size, seed=seed,
        process=process, rate=rate,
        prompt=loadgen.LengthDist("uniform", 4, prompt_hi),
        output=loadgen.LengthDist("uniform", 2, out_hi))
    stream = loadgen.generate_stream(load)

    clock = VirtualClock() if clock_kind == "virtual" else WallClock()
    metrics = ServeMetrics(clock, slots=slots)
    engine = ContinuousEngine(model, params, slots=slots, max_len=max_len,
                              queue_limit=queue_limit, metrics=metrics,
                              plan=plan)

    def drive():
        i = 0
        while i < len(stream) or engine.busy:
            now = clock.now()
            while i < len(stream) and stream[i].arrival <= now:
                r = stream[i]
                if not engine.submit(Request(r.rid, r.prompt, r.max_new),
                                     arrival=r.arrival):
                    break                     # backpressure: head waits
                i += 1
            if engine.step() == 0 and i < len(stream):
                # idle before the next arrival: jump a virtual clock,
                # yield a wall clock
                gap = stream[i].arrival - clock.now()
                if gap > 0:
                    if clock.kind == "virtual":
                        clock.advance(gap)
                    else:
                        time.sleep(min(gap, 0.01))

    if mesh is not None:
        with sharding.axis_rules(mesh):
            drive()
    else:
        drive()

    entry = {
        "config": name,
        "slots": slots,
        "max_len": max_len,
        "queue_limit": queue_limit,
        "mesh": None if mesh is None else
                {a: int(s) for a, s in mesh.shape.items()},
        "workload": load.describe(),
        "stream_digest": list(loadgen.stream_digest(stream)),
        "metrics": metrics.snapshot(),
        "requests_completed": len(engine.results),
    }
    if plan is not None:
        entry["compiled_blocks"] = plan.summary_rows()
        entry["compiled_count"] = len(plan.compiled)
    return entry


def check_bench(doc: Dict) -> None:
    """Schema gate for BENCH_serve.json (used by CI serve-smoke)."""
    if doc.get("schema") != "serve_bench/v1":
        raise ValueError(f"bad schema {doc.get('schema')!r}")
    entries = doc.get("entries")
    if not entries:
        raise ValueError("no entries")
    for e in entries:
        m = e.get("metrics", {})
        for k in REQUIRED_METRIC_KEYS:
            if k not in m:
                raise ValueError(f"{e.get('config')}: missing metric {k!r}")
        for h in ("ttft", "tpot", "e2e"):
            for k in REQUIRED_PCTL_KEYS:
                if k not in m[h]:
                    raise ValueError(f"{e.get('config')}: {h} missing {k!r}")
        if m["tokens_per_s"] <= 0:
            raise ValueError(f"{e.get('config')}: tokens_per_s "
                             f"{m['tokens_per_s']} <= 0")
        if not 0 < e["requests_completed"] <= m["requests"]["submitted"]:
            raise ValueError(f"{e.get('config')}: request accounting "
                             f"mismatch: {e['requests_completed']} completed "
                             f"of {m['requests']['submitted']} submitted")


def fmt_entry(e: Dict) -> str:
    m = e["metrics"]
    unit = "s" if m["clock"] == "wall" else "step"
    return (f"[serve_bench] {e['config']:16s} slots={e['slots']} "
            f"req={e['requests_completed']}/{m['requests']['submitted']} "
            f"tok/{unit}={m['tokens_per_s']:.1f} "
            f"ttft p50/p99={m['ttft']['p50']:.3g}/{m['ttft']['p99']:.3g} "
            f"tpot p50/p99={m['tpot']['p50']:.3g}/{m['tpot']['p99']:.3g} "
            f"util={m['slot_utilization']:.2f}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--configs", default="qwen2_7b,mamba2_130m",
                    help="comma-separated registry configs (reduced)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--process", default="poisson",
                    choices=("poisson", "bursty", "uniform"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--clock", default="wall", choices=("wall", "virtual"))
    ap.add_argument("--queue-limit", type=int, default=None)
    ap.add_argument("--prompt-hi", type=int, default=12)
    ap.add_argument("--out-hi", type=int, default=10)
    ap.add_argument("--no-plan", action="store_true",
                    help="skip the per-block compiler bridge")
    ap.add_argument("--mesh", default=None,
                    help="e.g. 'data=2,model=2' (needs that many devices)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale reduced run for CI")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    if args.smoke:
        args.requests = min(args.requests, 8)
        args.slots = min(args.slots, 2)
        args.prompt_hi = min(args.prompt_hi, 7)
        args.out_hi = min(args.out_hi, 5)

    mesh = parse_mesh(args.mesh)
    entries: List[Dict] = []
    for name in args.configs.split(","):
        name = name.strip()
        t0 = time.perf_counter()
        entry = run_config(
            name, slots=args.slots, requests=args.requests, rate=args.rate,
            process=args.process, seed=args.seed, clock_kind=args.clock,
            queue_limit=args.queue_limit, prompt_hi=args.prompt_hi,
            out_hi=args.out_hi, with_plan=not args.no_plan, mesh=mesh)
        entry["bench_wall_s"] = round(time.perf_counter() - t0, 3)
        entries.append(entry)
        print(fmt_entry(entry))

    doc = {"schema": "serve_bench/v1", "entries": entries}
    check_bench(doc)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"// json written to {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
