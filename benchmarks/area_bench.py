"""Area benchmark — structural footprint before/after resource sharing.

For every built-in GEMM schedule and every serving kernel (``flash``,
``decode``, ``ssd``), lower to HwIR, canonicalize, then apply the
sharing pipeline (``outline-subcircuits`` + ``share-units``) in both
modes — ``share`` (fold duplicate units behind muxes at ``serial=1``)
and ``serialize`` (additionally time-multiplex wide virtual units onto
narrow physical ones, trading cycles for area) — and record the
before/after area with its breakdown (summed datapath lanes, register
bits, RAM bytes, mux overhead, shared physical units, sub-module
definitions) plus the modeled cycle cost of the serialization.

Every "after" module is co-simulated against the LoopIR numpy oracle,
so the JSON never records an area win from hardware that stopped
computing the right answer.  Writes ``BENCH_area.json``
(schema ``area_bench/v1``, gated by :func:`check_bench` — used by the
CI share-smoke job; the gate also requires at least one entry with a
>= 20% area reduction).

  PYTHONPATH=src python benchmarks/area_bench.py            # full run
  PYTHONPATH=src python benchmarks/area_bench.py --smoke    # CI seconds
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

from repro.core import dse, hw_ir, hw_sim, ir_text, machine_model
from repro.core.machine_model import TPU_V5E
from repro.core.passes import PassManager
from repro.core.pipeline import SCHEDULES, compile_gemm
from repro.core.reproc import kernel_graph
from repro.core.rewrite import canonicalize
from repro.core.sharing import SHARING_MODES, set_sharing

GEMM_SIZE = 8
REQUIRED_ENTRY_KEYS = ("name", "mode", "before", "after", "reduction_pct",
                       "cosim")
REQUIRED_SIDE_KEYS = ("area", "total_lanes", "reg_bits", "vmem_bytes",
                      "mux_bits", "shared_units", "submodules", "fsm_states",
                      "cycles")


def _clone(mod: hw_ir.HwModule) -> hw_ir.HwModule:
    """Fresh module via the textual round trip (sharing mutates)."""
    return ir_text.parse_hw_module(ir_text.print_hw_module(mod))


def _side(mod: hw_ir.HwModule) -> Dict:
    cyc = machine_model.cycles(mod, TPU_V5E)
    return {
        "area": dse.area(mod),
        "total_lanes": mod.total_lanes(),
        "reg_bits": mod.register_bits(),
        "vmem_bytes": mod.mem_bytes(),
        "mux_bits": mod.mux_bits(),
        "shared_units": mod.shared_unit_count(),
        "submodules": len(mod.submodules),
        "fsm_states": mod.fsm_state_count(),
        "cycles": cyc.total,
    }


def bench_module(name: str, mod: hw_ir.HwModule, kernel, mode: str) -> Dict:
    before = _clone(mod)
    canonicalize(before)
    after = _clone(before)
    set_sharing(after, mode)

    b, a = _side(before), _side(after)
    rep = hw_sim.cosim(after, kernel, hw_sim.random_inputs(after),
                       machine=TPU_V5E)
    cyc_pct = abs(rep.cycle_ratio - 1.0) * 100.0
    return {
        "name": name,
        "mode": mode,
        "before": b,
        "after": a,
        "reduction_pct": round(100.0 * (b["area"] - a["area"])
                               / max(1, b["area"]), 2),
        "cosim": {
            "ok": bool(rep.checked and rep.max_abs_err <= 1e-5
                       and cyc_pct <= 10.0),
            "max_abs_err": rep.max_abs_err,
            "observed_cycles": rep.observed_cycles,
            "modeled_cycles": rep.modeled_cycles,
        },
    }


def _mlp_graph():
    """Two identical matmul+relu layers — the repeated subcircuit that
    ``outline-subcircuits`` folds into one instanced sub-module."""
    from repro.core import frontend as fe

    def mlp(x, w1, w2):
        return fe.relu(fe.matmul(fe.relu(fe.matmul(x, w1)), w2))

    return fe.trace(mlp, [fe.spec((8, 8))] * 3, name="mlp2")


def modules(smoke: bool):
    """Yield (name, HwModule, Kernel) for every subject."""
    scheds = ("inner_flattened",) if smoke else SCHEDULES
    for sched in scheds:
        ck = compile_gemm(GEMM_SIZE, GEMM_SIZE, GEMM_SIZE, schedule=sched,
                          want_jax=False, want_pallas=False)
        yield f"gemm{GEMM_SIZE}/{sched}", ck.hw_module, ck.kernel
    for kname in ("flash", "decode", "ssd"):
        g = kernel_graph(kname)
        kernel = PassManager.parse("lower").run(g).artifact
        yield kname, hw_ir.lower_to_hw(kernel), kernel
        if smoke:
            return
    # the outlining subject: two identical layers -> one sub-module def
    g = _mlp_graph()
    kernel = PassManager.parse(
        "lower{tile_m=4,tile_n=4,tile_k=4}").run(g).artifact
    yield "mlp2", hw_ir.lower_to_hw(kernel), kernel


def run(smoke: bool = False) -> List[Dict]:
    entries = []
    for name, mod, kernel in modules(smoke):
        for mode in SHARING_MODES:
            if mode == "none":
                continue
            t0 = time.perf_counter()
            e = bench_module(name, mod, kernel, mode)
            e["bench_wall_s"] = round(time.perf_counter() - t0, 3)
            entries.append(e)
            print(f"[area_bench] {name:24s} {mode:9s} "
                  f"area {e['before']['area']:>7} -> {e['after']['area']:>7} "
                  f"({-e['reduction_pct']:+.1f}%) "
                  f"cycles {e['before']['cycles']} -> {e['after']['cycles']} "
                  f"cosim={'ok' if e['cosim']['ok'] else 'FAIL'}")
    return entries


def check_bench(doc: Dict) -> None:
    """Schema gate for BENCH_area.json (used by CI share-smoke)."""
    if doc.get("schema") != "area_bench/v1":
        raise ValueError(f"bad schema {doc.get('schema')!r}")
    entries = doc.get("entries")
    if not entries:
        raise ValueError("no entries")
    for e in entries:
        for k in REQUIRED_ENTRY_KEYS:
            if k not in e:
                raise ValueError(f"{e.get('name')}: missing key {k!r}")
        for side in ("before", "after"):
            for k in REQUIRED_SIDE_KEYS:
                if k not in e[side]:
                    raise ValueError(
                        f"{e.get('name')}: {side} missing {k!r}")
        if not e["cosim"]["ok"]:
            raise ValueError(f"{e['name']}/{e['mode']}: cosim failed "
                             f"(max|err|={e['cosim']['max_abs_err']:.3e}, "
                             f"observed={e['cosim']['observed_cycles']} vs "
                             f"modeled={e['cosim']['modeled_cycles']})")
        # Pure time-multiplexed sharing (no outlining) must never grow
        # area.  Outlined entries may legitimately trade datapath for
        # control area (a sub-module definition is separate hardware, so
        # its units can no longer be time-shared with the parent's) —
        # for those the FSM must have shrunk instead.
        if e["after"]["submodules"] == 0:
            if e["after"]["area"] > e["before"]["area"]:
                raise ValueError(
                    f"{e['name']}/{e['mode']}: sharing grew area "
                    f"{e['before']['area']} -> {e['after']['area']}")
        elif e["after"]["fsm_states"] >= e["before"]["fsm_states"]:
            raise ValueError(
                f"{e['name']}/{e['mode']}: outlining neither shrank area "
                f"nor the FSM ({e['before']['fsm_states']} -> "
                f"{e['after']['fsm_states']} states)")
    if not any(e["reduction_pct"] >= 20.0 for e in entries):
        raise ValueError("no entry shows a >= 20% area reduction")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="one GEMM schedule + one kernel (CI seconds)")
    ap.add_argument("--out", default="BENCH_area.json")
    args = ap.parse_args(argv)

    doc = {"schema": "area_bench/v1", "entries": run(smoke=args.smoke)}
    check_bench(doc)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"// json written to {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
