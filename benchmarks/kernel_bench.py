"""Kernel micro-benchmarks: stagecc GEMM backends + flash attention +
SSD scan, wall-clock on this host + model-cycle derivations.

Prints CSV: name,us_per_call,derived.

``--compiled`` benches the *pipeline-compiled* serving kernels (the
TensorIR flash/ssd graphs lowered through PassManager schedules) against
the hand-written pallas kernels on identical data, and writes
``BENCH_kernels.json`` with wall-clock per backend plus the machine
model's cycle prediction for each compiled schedule.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import compile_gemm
from repro.kernels import ops


def _t(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)

    # GEMM: XLA vs stagecc-jax vs stagecc-pallas(interpret)
    for m, n, k in ((256, 256, 256), (512, 512, 512)):
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        xla = jax.jit(lambda x, y: x @ y)
        rows.append((f"gemm{m}/xla", _t(xla, a, b), 2 * m * n * k))
        ck = compile_gemm(m, n, k, schedule="tpu_mxu_kgrid")
        rows.append((f"gemm{m}/stagecc_jax", _t(ck.run_jax, a, b),
                     ck.cycles.total))
        if ck.run_pallas is not None:
            rows.append((f"gemm{m}/stagecc_pallas_interp",
                         _t(ck.run_pallas, a, b, reps=1), ck.cycles.total))

    # attention: XLA blockwise path vs pallas flash (interpret)
    q = jnp.asarray(rng.standard_normal((4, 512, 64)), jnp.float32)
    rows.append(("attn_512/xla",
                 _t(lambda *xs: ops.attention(*xs, backend="xla"), q, q, q),
                 4 * 4 * 512 * 512 * 64))
    rows.append(("attn_512/pallas_interp",
                 _t(lambda *xs: ops.attention(*xs, backend="pallas"),
                    q, q, q, reps=1), 4 * 4 * 512 * 512 * 64))

    # SSD
    S, H, P, N = 512, 8, 32, 16
    x = jnp.asarray(rng.standard_normal((S, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((S, H))) * 0.1, jnp.float32)
    A = jnp.asarray(-np.abs(rng.standard_normal(H)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((S, N)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((S, N)), jnp.float32)
    rows.append(("ssd_512/chunked_xla",
                 _t(lambda *xs: ops.ssd(*xs, backend="xla"), x, dt, A, B, C),
                 S * H * P * N))
    rows.append(("ssd_512/pallas_interp",
                 _t(lambda *xs: ops.ssd(*xs, backend="pallas"),
                    x, dt, A, B, C, reps=1), S * H * P * N))
    return rows


def run_compiled() -> list:
    """Hand-written pallas kernels vs the same math compiled through the
    stack (TensorIR graph -> PassManager schedule -> backends)."""
    from repro.core import frontend as fe, pipeline
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ssd_scan import ssd_scan

    rng = np.random.default_rng(0)
    rows = []

    # flash attention, one (batch*head) slice
    sq, sk, d = 32, 64, 8
    q = rng.standard_normal((1, sq, d)).astype(np.float32)
    k = rng.standard_normal((1, sk, d)).astype(np.float32)
    v = rng.standard_normal((1, sk, d)).astype(np.float32)
    qpos = np.arange(sq)[:, None] + (sk - sq)
    mask = np.where(np.arange(sk)[None, :] <= qpos, 0.0,
                    -1e30).astype(np.float32)
    sched = "lower{tile_m=8,tile_n=8,tile_k=8},fuse-epilogue,grid{vars=2}"
    ck = pipeline.compile_traced(fe.flash_attention_graph(sq, sk, d),
                                 pipeline=sched)
    gi = [q[0] / np.float32(np.sqrt(d)), k[0].T.copy(), v[0], mask]
    rows.append({
        "name": f"flash_{sq}x{sk}x{d}", "schedule": sched,
        "cycles_modeled": ck.cycles.total,
        "us_hand_pallas_interp": _t(
            lambda *xs: flash_attention(*xs, interpret=True),
            q, k, v, reps=1),
        "us_compiled_jax": _t(lambda *xs: ck.run_jax(*xs), *gi),
        "us_compiled_pallas_interp": (
            None if ck.run_pallas is None
            else _t(lambda *xs: ck.run_pallas(*xs), *gi, reps=1)),
    })

    # SSD scan, one head
    S, H, P, N = 64, 2, 4, 4
    x = rng.standard_normal((S, H, P)).astype(np.float32)
    dt = rng.uniform(0.01, 0.5, (S, H)).astype(np.float32)
    A = np.asarray([-0.5, -0.9], np.float32)
    B = rng.standard_normal((S, N)).astype(np.float32)
    C = rng.standard_normal((S, N)).astype(np.float32)
    a = np.repeat(np.exp(dt[:, 0] * A[0])[:, None], P * N, axis=1)
    u = ((dt[:, 0, None] * x[:, 0, :])[:, :, None]
         * B[:, None, :]).reshape(S, P * N)
    ct = np.broadcast_to(C[:, None, :], (S, P, N)).reshape(S, P * N).copy()
    g = np.kron(np.eye(P), np.ones((N, 1))).astype(np.float32)
    sched = "lower{tile_m=8,tile_n=8,tile_k=8},fuse-epilogue,grid{vars=1}"
    ck = pipeline.compile_traced(fe.ssd_scan_graph(S, P, N), pipeline=sched)
    gi = [a.astype(np.float32), u.astype(np.float32), ct, g]
    rows.append({
        "name": f"ssd_{S}x{P}x{N}", "schedule": sched,
        "cycles_modeled": ck.cycles.total,
        "us_hand_pallas_interp": _t(
            lambda *xs: ssd_scan(*xs, chunk=16, interpret=True),
            x, dt, A, B, C, reps=1),
        "us_compiled_jax": _t(lambda *xs: ck.run_jax(*xs), *gi),
        "us_compiled_pallas_interp": (
            None if ck.run_pallas is None
            else _t(lambda *xs: ck.run_pallas(*xs), *gi, reps=1)),
    })
    return rows


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--compiled", action="store_true",
                   help="bench hand-written vs pipeline-compiled serving "
                        "kernels and write a JSON report")
    p.add_argument("--out", default="BENCH_kernels.json",
                   help="with --compiled: JSON report path "
                        "(default BENCH_kernels.json)")
    args = p.parse_args(argv)
    if args.compiled:
        rows = run_compiled()
        with open(args.out, "w") as f:
            json.dump({"rows": rows}, f, indent=2)
        print(f"{'name':14s} {'hand_us':>10s} {'compiled_jax_us':>16s} "
              f"{'compiled_pl_us':>15s} {'cycles':>10s}")
        for r in rows:
            pl_us = r["us_compiled_pallas_interp"]
            print(f"{r['name']:14s} {r['us_hand_pallas_interp']:10.1f} "
                  f"{r['us_compiled_jax']:16.1f} "
                  f"{(0.0 if pl_us is None else pl_us):15.1f} "
                  f"{r['cycles_modeled']:10d}")
        print(f"// json written to {args.out}")
        return
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
