"""Kernel micro-benchmarks: stagecc GEMM backends + flash attention +
SSD scan, wall-clock on this host + model-cycle derivations.

Prints CSV: name,us_per_call,derived.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import compile_gemm
from repro.kernels import ops


def _t(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)

    # GEMM: XLA vs stagecc-jax vs stagecc-pallas(interpret)
    for m, n, k in ((256, 256, 256), (512, 512, 512)):
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        xla = jax.jit(lambda x, y: x @ y)
        rows.append((f"gemm{m}/xla", _t(xla, a, b), 2 * m * n * k))
        ck = compile_gemm(m, n, k, schedule="tpu_mxu_kgrid")
        rows.append((f"gemm{m}/stagecc_jax", _t(ck.run_jax, a, b),
                     ck.cycles.total))
        if ck.run_pallas is not None:
            rows.append((f"gemm{m}/stagecc_pallas_interp",
                         _t(ck.run_pallas, a, b, reps=1), ck.cycles.total))

    # attention: XLA blockwise path vs pallas flash (interpret)
    q = jnp.asarray(rng.standard_normal((4, 512, 64)), jnp.float32)
    rows.append(("attn_512/xla",
                 _t(lambda *xs: ops.attention(*xs, backend="xla"), q, q, q),
                 4 * 4 * 512 * 512 * 64))
    rows.append(("attn_512/pallas_interp",
                 _t(lambda *xs: ops.attention(*xs, backend="pallas"),
                    q, q, q, reps=1), 4 * 4 * 512 * 512 * 64))

    # SSD
    S, H, P, N = 512, 8, 32, 16
    x = jnp.asarray(rng.standard_normal((S, H, P)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((S, H))) * 0.1, jnp.float32)
    A = jnp.asarray(-np.abs(rng.standard_normal(H)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((S, N)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((S, N)), jnp.float32)
    rows.append(("ssd_512/chunked_xla",
                 _t(lambda *xs: ops.ssd(*xs, backend="xla"), x, dt, A, B, C),
                 S * H * P * N))
    rows.append(("ssd_512/pallas_interp",
                 _t(lambda *xs: ops.ssd(*xs, backend="pallas"),
                    x, dt, A, B, C, reps=1), S * H * P * N))
    return rows


def main():
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
