"""TABLE I reproduction: consumed clock cycles of GEMM under the paper's
two schedules (nested vs inner-flattened), sizes 4..128, plus the
TPU-native schedules as the beyond-paper comparison.

Cycle counts are derived *structurally* from the lowered HwIR module of
each schedule (``CompiledKernel.hw_module`` — FSM transitions, datapath
unit latencies, memory-port traffic), the way the paper reads them off
Vivado simulation of the generated RTL; no LoopIR heuristics are
involved.  The flattened-FSM state count of each module is reported
alongside as the control-hardware witness.

Since the HwSim subsystem, each modeled count is cross-checked by
actually *executing* the module: ``hw_sim.simulate`` walks the FSM
cycle-by-cycle against random inputs and reports the observed total,
which lands alongside the analytic number (``*_sim_cycles`` rows, plus
a ``sim_vs_model_pct`` deviation row).  Simulation is event-per-step,
so sizes above ``SIM_MAX_SIZE`` report NaN rather than grinding through
millions of scalar MAC events.

Prints CSV: name,us_per_call,derived
  - structural HwIR cycles for both paper schedules + paper's numbers
  - observed (simulated) cycles for both paper schedules
  - measured wall time of the stagecc jax backend executing the same
    kernels on this host (correctness-bearing, not roofline-bearing).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.pipeline import compile_gemm

PAPER = {4: (1_498, 1_114), 8: (10_762, 7_946), 16: (81_802, 60_298),
         32: (867_594, 470_282), 64: (5_042_698, 3_527_115),
         128: (38_324_504, 26_806_047)}

SIZES = (4, 8, 16, 32, 64, 128)

#: simulate (event-per-step) only up to this GEMM size
SIM_MAX_SIZE = 32


def _time_call(fn, *args, reps=3):
    fn(*args)                                  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    elif isinstance(out, (list, tuple)) and hasattr(out[0],
                                                    "block_until_ready"):
        out[0].block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list:
    rows = []
    for s in SIZES:
        nested = compile_gemm(s, s, s, schedule="nested",
                              want_jax=True, want_pallas=False)
        flat = compile_gemm(s, s, s, schedule="inner_flattened",
                            want_jax=False, want_pallas=False)
        mxu = compile_gemm(s, s, s, schedule="tpu_mxu_kgrid",
                           want_jax=False, want_pallas=False)
        pn, pf = PAPER[s]
        # ck.cycles/ck.resources are structural — computed from ck.hw_module
        # (FSM/datapath walk), not from the LoopIR schedule.
        ncyc = nested.cycles.total
        fcyc = flat.cycles.total
        rng = np.random.default_rng(s)
        a = rng.standard_normal((s, s)).astype(np.float32)
        b = rng.standard_normal((s, s)).astype(np.float32)
        us = _time_call(nested.run_jax, a, b) if s <= 32 else float("nan")
        rows.append((f"table1/gemm{s}x{s}/nested_hw_cycles", us, ncyc))
        rows.append((f"table1/gemm{s}x{s}/flattened_hw_cycles",
                     float("nan"), fcyc))
        rows.append((f"table1/gemm{s}x{s}/paper_nested", float("nan"), pn))
        rows.append((f"table1/gemm{s}x{s}/paper_flattened", float("nan"),
                     pf))
        rows.append((f"table1/gemm{s}x{s}/model_ratio", float("nan"),
                     round(ncyc / fcyc, 3)))
        # observed cycles: execute the module in HwSim and compare with
        # the analytic model (shared unit latencies, so deviation is a
        # scheduling-effect witness, not a constants mismatch)
        if s <= SIM_MAX_SIZE:
            # check=False: numeric co-sim is covered by tests; here only
            # the observed cycle count is benchmark-bearing
            nsim = nested.simulate(a, b, check=False).observed_cycles
            fsim = flat.simulate(a, b, check=False).observed_cycles
            dev = 100.0 * max(abs(nsim - ncyc) / ncyc,
                              abs(fsim - fcyc) / fcyc)
            rows.append((f"table1/gemm{s}x{s}/nested_sim_cycles",
                         float("nan"), nsim))
            rows.append((f"table1/gemm{s}x{s}/flattened_sim_cycles",
                         float("nan"), fsim))
            rows.append((f"table1/gemm{s}x{s}/sim_vs_model_pct",
                         float("nan"), round(dev, 3)))
        else:
            rows.append((f"table1/gemm{s}x{s}/nested_sim_cycles",
                         float("nan"), float("nan")))
            rows.append((f"table1/gemm{s}x{s}/flattened_sim_cycles",
                         float("nan"), float("nan")))
            rows.append((f"table1/gemm{s}x{s}/sim_vs_model_pct",
                         float("nan"), float("nan")))
        rows.append((f"table1/gemm{s}x{s}/nested_fsm_states", float("nan"),
                     nested.resources.fsm_states))
        rows.append((f"table1/gemm{s}x{s}/flattened_fsm_states",
                     float("nan"), flat.resources.fsm_states))
        rows.append((f"table1/gemm{s}x{s}/tpu_mxu_cycles", float("nan"),
                     mxu.cycles.total))
    return rows


def main():
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
