"""Pareto frontier of the GEMM design space — the DSE closing the
paper's manual "simulate in Vivado, pick a schedule" loop.

For each GEMM size, ``repro.core.dse.explore`` searches schedule
programs (the paper's two points — nested and inner-flattened — plus
the split+unroll replication ladder, ``@stream`` double-buffering, the
memory-placement knob, the resource-sharing families (``set-sharing``
outlining + time-multiplexed unit bindings) and the grid-mapped MXU
tilings), prices every
candidate structurally off its lowered HwIR module, and reports the
cycles × area frontier.  Frontier points at the smallest size are
additionally co-simulated against the numpy oracle, mirroring the
paper's RTL validation.

Prints ``name,us_per_call,derived`` CSV rows (one ``cycles`` and one
``area`` row per candidate; ``frontier/<n>`` rows mark the frontier
size) followed by an ASCII frontier plot in ``#``-comment lines.

The **second axis** is the fleet frontier: ``fabric.explore_fleet``
crosses the single-kernel frontier with copy counts behind a shared
crossbar and ranks fleets on *throughput under contention*
(requests/s against a saturating traffic mix) × total area — rows
under ``pareto/fleet/...`` plus a second ASCII plot.
Standalone: ``PYTHONPATH=src python -m benchmarks.pareto [--plot-only]``.
"""

from __future__ import annotations

import sys

from repro.core import dse
from repro.core.reproc import quickstart_gemm

SIZES = (8, 16, 32)
#: co-simulate the whole frontier at this size (event-per-step sim)
VALIDATE_SIZE = 8


def explore_size(s: int) -> dse.DseResult:
    g = quickstart_gemm(s, s, s, epilogue="none")
    return dse.explore(g, validate_top=64 if s == VALIDATE_SIZE else 0)


def run() -> list:
    rows = []
    for s in SIZES:
        res = explore_size(s)
        for i, c in enumerate(sorted(res.candidates, key=lambda c: c.key)):
            tag = "frontier" if c.on_frontier else "dominated"
            base = f"pareto/gemm{s}x{s}x{s}/{c.point.family}.{i}/{tag}"
            rows.append((f"{base}/cycles", float("nan"), c.cycles.total))
            rows.append((f"{base}/area", float("nan"), c.area))
            rows.append((f"{base}/total_lanes", float("nan"),
                         c.resources.total_lanes))
            rows.append((f"{base}/mux_bits", float("nan"),
                         c.resources.mux_bits))
            rows.append((f"{base}/shared_units", float("nan"),
                         c.resources.shared_units))
        rows.append((f"pareto/gemm{s}x{s}x{s}/frontier_points",
                     float("nan"), len(res.frontier)))
        rows.append((f"pareto/gemm{s}x{s}x{s}/cosim_ok", float("nan"),
                     int(all(v.ok for v in res.validations))
                     if res.validations else float("nan")))
    return rows


#: fleet axis: copies searched per kernel and frontier points per kernel
FLEET_SIZE = 8
FLEET_MAX_COPIES = 2
FLEET_PER_KERNEL = 3


def explore_fleet_size(s: int):
    import dataclasses

    from repro.core import fabric
    from repro.core.host_bridge import AXI4
    from repro.core.machine_model import TPU_V5E
    from repro.core.pipeline import compile_gemm

    ck = compile_gemm(s, s, s, schedule="nested",
                      want_jax=False, want_pallas=False)
    name = f"gemm{s}"
    mix = fabric.TrafficMix("steady", ((name, 1.0),),
                            num_requests=8, process="poisson",
                            rate=1.0, seed=0)
    service = fabric.transaction_cost(ck.hw_module, AXI4,
                                      ck.cycles.total).total
    mix = dataclasses.replace(
        mix, cycles_per_unit=fabric.saturating_cycles_per_unit(
            mix, service, load_factor=2.0 * FLEET_MAX_COPIES))
    return fabric.explore_fleet({name: ck.graph}, mix, machine=TPU_V5E,
                                per_kernel=FLEET_PER_KERNEL,
                                max_copies=FLEET_MAX_COPIES,
                                validate_top=2)


def run_fleet() -> list:
    rows = []
    res = explore_fleet_size(FLEET_SIZE)
    s = FLEET_SIZE
    for i, c in enumerate(res.frontier):
        base = f"pareto/fleet/gemm{s}x{s}x{s}/{c.spec()}/frontier"
        rows.append((f"{base}/requests_per_s", float("nan"),
                     round(c.model_rps, 1)))
        rows.append((f"{base}/area", float("nan"), c.area))
        rows.append((f"{base}/speedup_vs_serialized", float("nan"),
                     round(c.speedup, 3)))
    rows.append((f"pareto/fleet/gemm{s}x{s}x{s}/frontier_points",
                 float("nan"), len(res.frontier)))
    rows.append((f"pareto/fleet/gemm{s}x{s}x{s}/sim_validated_ok",
                 float("nan"),
                 int(all(v.ok for v in res.validations))
                 if res.validations else float("nan")))
    return rows


def ascii_fleet_plot(res, width: int = 64, height: int = 12) -> str:
    """Scatter of requests/s (x) vs area (y, log) over ALL priced
    fleets; '*' = on the throughput-under-contention × area frontier."""
    import math

    pts = [(c.model_rps, c.area, c.on_frontier) for c in res.candidates]
    if not pts:
        return "# (no fleets)"
    xs = [p[0] for p in pts]
    ly = [math.log10(max(p[1], 1)) for p in pts]
    x0, x1 = min(xs), max(xs) or 1.0
    y0, y1 = min(ly), max(ly) or 1.0
    grid = [[" "] * width for _ in range(height)]
    for (rps, ar, front), gy in zip(pts, ly):
        col = int((rps - x0) / max(x1 - x0, 1e-9) * (width - 1))
        row = int((gy - y0) / max(y1 - y0, 1e-9) * (height - 1))
        grid[height - 1 - row][col] = "*" if front else "o"
    lines = ["# fleet: requests/s under contention (x) vs total area "
             "(y, log); '*' frontier / 'o' dominated"]
    for r in grid:
        lines.append("# |" + "".join(r) + "|")
    lines.append(f"# +{'-' * width}+  x: {x0:,.0f}..{x1:,.0f} req/s, "
                 f"y: 10^{y0:.1f}..10^{y1:.1f} area")
    return "\n".join(lines)


def ascii_plot(res: dse.DseResult, width: int = 64, height: int = 16) -> str:
    """Log-log scatter of cycles (x) vs area (y); '*' = frontier."""
    import math

    pts = [(c.cycles.total, c.area, c.on_frontier) for c in res.candidates]
    if not pts:
        return "# (no candidates)"
    lx = [math.log10(max(p[0], 1)) for p in pts]
    ly = [math.log10(max(p[1], 1)) for p in pts]
    x0, x1 = min(lx), max(lx) or 1.0
    y0, y1 = min(ly), max(ly) or 1.0
    grid = [[" "] * width for _ in range(height)]
    for (cyc, ar, front), gx, gy in zip(pts, lx, ly):
        col = int((gx - x0) / max(x1 - x0, 1e-9) * (width - 1))
        row = int((gy - y0) / max(y1 - y0, 1e-9) * (height - 1))
        grid[height - 1 - row][col] = "*" if front else "o"
    lines = [f"# {res.graph_name}: cycles (x, log) vs area (y, log); "
             f"'*' frontier / 'o' dominated"]
    for r in grid:
        lines.append("# |" + "".join(r) + "|")
    lines.append(f"# +{'-' * width}+  x: 10^{x0:.1f}..10^{x1:.1f} cycles, "
                 f"y: 10^{y0:.1f}..10^{y1:.1f} area")
    return "\n".join(lines)


def main():
    plot_only = "--plot-only" in sys.argv
    if not plot_only:
        print("name,us_per_call,derived")
        for name, us, derived in run():
            print(f"{name},{us:.2f},{derived}")
        for name, us, derived in run_fleet():
            print(f"{name},{us:.2f},{derived}")
    print(ascii_plot(explore_size(SIZES[-1])))
    print(ascii_fleet_plot(explore_fleet_size(FLEET_SIZE)))


if __name__ == "__main__":
    main()
