"""Fig. 3 reproduction: hardware consumption of nested (time-division
multiplexed — constant) vs inner-flattened (spatial — proportional)
GEMM schedules, in TPU resource units (compute lanes / VREG tiles /
VMEM bytes standing in for DSP / FF-LUT / BRAM).

Resource numbers are read *structurally* off the lowered HwIR module of
each schedule (``CompiledKernel.hw_module``): datapath-unit lanes and
copies, register banks plus counter/FSM state bits, RAM bytes, and the
flattened FSM state count — the same quantities Vivado's utilisation
report gives the paper for its generated RTL.

Prints CSV: name,us_per_call,derived.
"""

from __future__ import annotations

from repro.core.pipeline import compile_gemm

SIZES = (4, 8, 16, 32, 64, 128)


def run() -> list:
    rows = []
    for s in SIZES:
        for sched in ("nested", "inner_flattened", "tpu_mxu_kgrid"):
            ck = compile_gemm(s, s, s, schedule=sched,
                              want_jax=False, want_pallas=False)
            r = ck.resources        # structural, from ck.hw_module
            rows.append((f"fig3/gemm{s}x{s}/{sched}/lanes", float("nan"),
                         r.compute_lanes))
            rows.append((f"fig3/gemm{s}x{s}/{sched}/vregs", float("nan"),
                         r.vreg_tiles))
            rows.append((f"fig3/gemm{s}x{s}/{sched}/vmem_bytes",
                         float("nan"), r.vmem_bytes))
            rows.append((f"fig3/gemm{s}x{s}/{sched}/fsm_states",
                         float("nan"), r.fsm_states))
            rows.append((f"fig3/gemm{s}x{s}/{sched}/reg_bits",
                         float("nan"), r.reg_bits))
            # area breakdown (summed datapath vs peak, mux overhead of
            # time-multiplexed units, shared physical units)
            rows.append((f"fig3/gemm{s}x{s}/{sched}/total_lanes",
                         float("nan"), r.total_lanes))
            rows.append((f"fig3/gemm{s}x{s}/{sched}/mux_bits",
                         float("nan"), r.mux_bits))
            rows.append((f"fig3/gemm{s}x{s}/{sched}/shared_units",
                         float("nan"), r.shared_units))
    return rows


def main():
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
