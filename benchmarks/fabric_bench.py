"""Multi-kernel fabric benchmark — the throughput-under-contention
trajectory.

Schedules deterministic request streams (``repro.serve.loadgen``
arrival processes scaled to device cycles) over fleets of compiled
accelerators sharing one crossbar (``repro.core.fabric``), and records
``BENCH_fabric.json``: requests/s of the **serialized single-kernel
baseline** (back-to-back ``run_transaction`` — the seed behaviour) vs
the **contention-aware overlap scheduler** (per-beat crossbar
arbitration, DMA overlapped with compute), the fabric event-simulator
cross-check of the machine model (pricing symmetry: the two must agree
within 10%), crossbar utilization, per-slot queue-depth p50/p99, and
the fleet-level DSE frontier (requests/s × total area) with its
sim-validated top points.

This is the first BENCH where the number must go *up*: every entry's
overlap throughput must beat its serialized baseline by ≥1.3×, and CI
(``fabric-smoke``) re-runs the smoke config twice under the virtual
clock and byte-diffs the JSON.

  PYTHONPATH=src python benchmarks/fabric_bench.py            # full fleets
  PYTHONPATH=src python benchmarks/fabric_bench.py --smoke    # CI seconds
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, List, Optional, Tuple

#: every entry's overlap scheduler must beat serialized dispatch by this
SPEEDUP_FLOOR = 1.3
#: fabric machine model vs fabric event simulator agreement gate
MODEL_SIM_TOL_PCT = 10.0


def _fleets(smoke: bool):
    """Yield (fleet name, {kernel: (graph, HwModule, Kernel)}, copies)."""
    from repro.core import hw_ir
    from repro.core.passes import PassManager
    from repro.core.pipeline import compile_gemm
    from repro.core.reproc import kernel_graph

    ck = compile_gemm(8, 8, 8, schedule="nested",
                      want_jax=False, want_pallas=False)
    yield ("gemm8x2",
           {"gemm8": (ck.graph, ck.hw_module, ck.kernel)},
           {"gemm8": 2})
    if smoke:
        return
    g = kernel_graph("flash")
    kernel = PassManager.parse("lower").run(g).artifact
    hw = hw_ir.lower_to_hw(kernel)
    yield ("gemm8+flash",
           {"gemm8": (ck.graph, ck.hw_module, ck.kernel),
            "flash": (g, hw, kernel)},
           {"gemm8": 1, "flash": 1})


def _mixes(names: List[str], requests: int) -> List:
    """The two traffic mixes per fleet: steady Poisson (even weights)
    and bursty with load skewed onto the first kernel."""
    from repro.core.fabric import TrafficMix

    even = tuple((n, 1.0) for n in names)
    skew = tuple((n, 3.0 if i == 0 else 1.0) for i, n in enumerate(names))
    return [
        TrafficMix("steady_poisson", even, num_requests=requests,
                   process="poisson", rate=1.0, seed=0),
        TrafficMix("bursty_skewed", skew, num_requests=requests,
                   process="bursty", rate=1.0, seed=1),
    ]


def run_entry(fleet_name: str, parts: Dict, copies: Dict[str, int],
              mix, *, with_dse: bool, dse_per_kernel: int,
              seed: int) -> Dict:
    from repro.core import machine_model
    from repro.core.fabric import (explore_fleet, fabric_stream, make_fleet,
                                   saturating_cycles_per_unit,
                                   transaction_cost)
    from repro.core.host_bridge import AXI4

    fab = make_fleet({n: (hw, k) for n, (_, hw, k) in parts.items()},
                     copies=copies, crossbar=AXI4)
    # offer ~2x the whole fleet's capacity so the stream actually queues
    w = dict(mix.weights)
    wsum = sum(w.values())
    mean_service = sum(
        transaction_cost(hw, AXI4,
                         machine_model.cycles(hw).total).total * w[n]
        for n, (_, hw, _) in parts.items()) / wsum
    mix = dataclasses.replace(mix, cycles_per_unit=saturating_cycles_per_unit(
        mix, mean_service, load_factor=2.0 * len(fab.slots)))
    stream = fabric_stream(mix)

    ser = fab.model(stream, overlap=False)
    ovl = fab.model(stream, overlap=True)
    pri = dataclasses.replace(fab, policy="priority").model(
        stream, overlap=True)
    sim = fab.simulate(stream, overlap=True, seed=seed)
    speedup = ovl.requests_per_s / ser.requests_per_s
    dev_pct = (100.0 * abs(sim.requests_per_s - ovl.requests_per_s)
               / max(ovl.requests_per_s, 1e-12))

    entry = {
        "fleet": fleet_name,
        "mix": mix.describe(),
        "slots": [s.name for s in fab.slots],
        "requests": len(stream),
        "serialized": ser.to_json(),
        "overlap": ovl.to_json(),
        "overlap_priority": pri.to_json(),
        "overlap_sim": sim.to_json(),
        "speedup": round(speedup, 4),
        "model_vs_sim_pct": round(dev_pct, 4),
    }
    if with_dse:
        graphs = {n: g for n, (g, _, _) in parts.items()}
        res = explore_fleet(graphs, mix, per_kernel=dse_per_kernel,
                            max_copies=2, validate_top=2, seed=seed)
        entry["fleet_dse"] = {
            "frontier": [{"fleet": c.spec(), "area": c.area,
                          "requests_per_s": round(c.model_rps, 3),
                          "speedup": round(c.speedup, 4)}
                         for c in res.frontier],
            "validations": [{"fleet": v.candidate.spec(),
                             "sim_rps": round(v.sim_rps, 3),
                             "model_rps": round(v.model_rps, 3),
                             "deviation_pct": round(v.deviation_pct, 4),
                             "ok": v.ok}
                            for v in res.validations],
        }
    return entry


def check_bench(doc: Dict) -> None:
    """Schema gate for BENCH_fabric.json (used by CI fabric-smoke and
    ``make bench-check``): structure, the ≥1.3× overlap-vs-serialized
    floor, and the ≤10% model-vs-sim symmetry gate on every entry."""
    if doc.get("schema") != "fabric_bench/v1":
        raise ValueError(f"bad schema {doc.get('schema')!r}")
    entries = doc.get("entries")
    if not entries:
        raise ValueError("no entries")
    for e in entries:
        tag = f"{e.get('fleet')}/{e.get('mix', {}).get('name')}"
        for k in ("fleet", "mix", "slots", "serialized", "overlap",
                  "overlap_sim", "speedup", "model_vs_sim_pct"):
            if k not in e:
                raise ValueError(f"{tag}: missing key {k!r}")
        for side in ("serialized", "overlap", "overlap_sim"):
            sec = e[side]
            if sec["requests_per_s"] <= 0:
                raise ValueError(f"{tag}: {side} requests_per_s <= 0")
            if sec["completed"] != sec["requests"]:
                raise ValueError(f"{tag}: {side} dropped requests "
                                 f"({sec['completed']}/{sec['requests']})")
            if not 0.0 <= sec["crossbar_utilization"] <= 1.0:
                raise ValueError(f"{tag}: {side} crossbar utilization "
                                 f"{sec['crossbar_utilization']} not in "
                                 f"[0, 1]")
            for s in sec["slots"]:
                for k in ("p50", "p99"):
                    if k not in s["queue_depth"]:
                        raise ValueError(f"{tag}: slot {s['name']} "
                                         f"queue_depth missing {k!r}")
        if e["speedup"] < SPEEDUP_FLOOR:
            raise ValueError(
                f"{tag}: overlap speedup {e['speedup']}x is below the "
                f"{SPEEDUP_FLOOR}x floor over serialized dispatch")
        if e["model_vs_sim_pct"] > MODEL_SIM_TOL_PCT:
            raise ValueError(
                f"{tag}: event sim deviates {e['model_vs_sim_pct']}% "
                f"from the machine model (> {MODEL_SIM_TOL_PCT}%)")
        for v in e.get("fleet_dse", {}).get("validations", ()):
            if not v["ok"] or v["deviation_pct"] > MODEL_SIM_TOL_PCT:
                raise ValueError(
                    f"{tag}: fleet frontier point {v['fleet']!r} failed "
                    f"sim validation (dev {v['deviation_pct']}%)")


def fmt_entry(e: Dict) -> str:
    ovl = e["overlap"]
    return (f"[fabric_bench] {e['fleet']:12s} {e['mix']['name']:15s} "
            f"req/s {e['serialized']['requests_per_s']:>10,.0f} -> "
            f"{ovl['requests_per_s']:>10,.0f} ({e['speedup']:.2f}x) "
            f"xbar {ovl['crossbar_utilization']:.1%} "
            f"sim dev {e['model_vs_sim_pct']:.2f}% "
            f"frontier {len(e.get('fleet_dse', {}).get('frontier', []))}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-dse", action="store_true",
                    help="skip the fleet-level DSE section")
    ap.add_argument("--dse-per-kernel", type=int, default=2,
                    help="frontier points taken per kernel in fleet DSE")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale reduced run for CI; drops "
                         "wall-time fields so the JSON is byte-"
                         "reproducible run to run")
    ap.add_argument("--out", default="BENCH_fabric.json")
    args = ap.parse_args(argv)

    if args.smoke:
        args.requests = min(args.requests, 16)

    entries: List[Dict] = []
    for fleet_name, parts, copies in _fleets(args.smoke):
        for mix in _mixes(list(parts), args.requests):
            t0 = time.perf_counter()
            entry = run_entry(fleet_name, parts, copies, mix,
                              with_dse=not args.no_dse,
                              dse_per_kernel=args.dse_per_kernel,
                              seed=args.seed)
            if not args.smoke:
                entry["bench_wall_s"] = round(time.perf_counter() - t0, 3)
            entries.append(entry)
            print(fmt_entry(entry))

    doc = {"schema": "fabric_bench/v1", "entries": entries}
    check_bench(doc)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"// json written to {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
