"""Roofline table builder — reads the dry-run JSONs and renders the
EXPERIMENTS.md §Roofline table (single-pod) plus the multi-pod deltas.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(dirpath: str) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def render(recs: List[Dict], mesh: str = "16x16") -> str:
    rows = []
    head = (f"| arch | shape | compute s | memory s | collective s | "
            f"bottleneck | useful (6ND/HLO) | state/dev | temp/dev |")
    sep = "|" + "---|" * 9
    rows.append(head)
    rows.append(sep)
    for r in recs:
        if r.get("skipped") or r.get("mesh") != mesh or r.get("tag"):
            continue
        ro = r["roofline"]
        mem = r.get("memory_analysis", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.4f} | "
            f"{ro['memory_s']:.4f} | {ro['collective_s']:.4f} | "
            f"**{ro['bottleneck']}** | {ro['useful_ratio']:.3f} | "
            f"{fmt_bytes(r['state_bytes_per_device'])} | "
            f"{fmt_bytes(mem.get('temp_size_in_bytes', 0))} |")
    return "\n".join(rows)


def multi_pod_deltas(recs: List[Dict]) -> str:
    single = {(r["arch"], r["shape"]): r for r in recs
              if r.get("mesh") == "16x16" and not r.get("skipped")
              and not r.get("tag")}
    multi = {(r["arch"], r["shape"]): r for r in recs
             if r.get("mesh") == "2x16x16" and not r.get("skipped")
             and not r.get("tag")}
    rows = ["| arch | shape | coll bytes 1-pod | coll bytes 2-pod | ratio |",
            "|---|---|---|---|---|"]
    for key in sorted(single):
        if key not in multi:
            continue
        c1 = single[key]["collectives"]["total_bytes"]
        c2 = multi[key]["collectives"]["total_bytes"]
        rows.append(f"| {key[0]} | {key[1]} | {fmt_bytes(c1)} | "
                    f"{fmt_bytes(c2)} | {c2 / max(c1, 1):.2f}x |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    recs = load(args.dir)
    if args.csv:
        print("arch,shape,mesh,compute_s,memory_s,collective_s,bottleneck,"
              "useful,flops_dev,coll_bytes")
        for r in recs:
            if r.get("skipped") or r.get("tag"):
                continue
            ro = r["roofline"]
            print(f"{r['arch']},{r['shape']},{r['mesh']},"
                  f"{ro['compute_s']:.6f},{ro['memory_s']:.6f},"
                  f"{ro['collective_s']:.6f},{ro['bottleneck']},"
                  f"{ro['useful_ratio']:.4f},{ro['flops_per_device']:.3e},"
                  f"{ro['coll_bytes_per_device']:.3e}")
        return
    n_ok = sum(1 for r in recs if not r.get("skipped") and not r.get("tag"))
    n_skip = sum(1 for r in recs if r.get("skipped"))
    print(f"# Roofline — {n_ok} compiled cells, {n_skip} skip records\n")
    print("## single-pod (16x16 = 256 chips)\n")
    print(render(recs, "16x16"))
    print("\n## multi-pod collective growth (2x16x16 = 512 chips)\n")
    print(multi_pod_deltas(recs))


if __name__ == "__main__":
    main()
