"""Benchmark aggregator — one section per paper table/figure plus the
framework-level suites.  Prints ``name,us_per_call,derived`` CSV.

  table1   — paper TABLE I (GEMM cycles, nested vs inner-flattened)
  fig3     — paper Fig. 3 (resource consumption vs size)
  kernels  — stagecc GEMM / flash attention / SSD wall-clock
  train    — reduced-model train-step wall-clock + tokens/s
  roofline — summary over results/dryrun (if present)
"""

from __future__ import annotations

import os
import sys
import time


def _train_bench() -> list:
    import jax
    import numpy as np
    from repro.configs.base import get_config, reduced
    from repro.data.pipeline import DataConfig, Pipeline
    from repro.models.model import Model, RunConfig
    from repro.optim.optimizer import adamw
    from repro.train.step import TrainConfig, init_state, make_train_step

    rows = []
    for arch in ("minicpm_2b", "mamba2_130m", "deepseek_v2_236b"):
        cfg = reduced(get_config(arch))
        model = Model(cfg, RunConfig(max_seq=64))
        opt = adamw(lambda s: 1e-3)
        step = jax.jit(make_train_step(model, opt, TrainConfig()),
                       donate_argnums=(0,))
        pipe = Pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                   global_batch=4))
        state = init_state(model, opt, jax.random.PRNGKey(0))
        batch = pipe.jax_batch(0)
        state, m = step(state, batch)            # compile
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        reps = 3
        for i in range(reps):
            state, m = step(state, pipe.jax_batch(i + 1))
        jax.block_until_ready(m["loss"])
        us = (time.perf_counter() - t0) / reps * 1e6
        toks = 4 * 32
        rows.append((f"train/{arch}_reduced/step", us,
                     round(toks / (us / 1e6))))
    return rows


def _roofline_rows() -> list:
    import glob
    import json
    rows = []
    for f in sorted(glob.glob("results/dryrun/*__16x16.json")):
        with open(f) as fh:
            r = json.load(fh)
        if r.get("skipped") or r.get("tag"):
            continue
        ro = r["roofline"]
        dom = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        rows.append((f"roofline/{r['arch']}/{r['shape']}/dominant_s",
                     float("nan"), round(dom, 4)))
    return rows


def main() -> None:
    from benchmarks import fig3_resources, kernel_bench, pareto, table1_cycles

    print("name,us_per_call,derived")
    sections = [("table1", table1_cycles.run),
                ("fig3", fig3_resources.run),
                ("pareto", pareto.run),
                ("kernels", kernel_bench.run),
                ("train", _train_bench)]
    for name, fn in sections:
        try:
            for row in fn():
                n, us, d = row
                print(f"{n},{us:.2f},{d}")
        except Exception as e:  # pragma: no cover
            print(f"{name}/ERROR,nan,{type(e).__name__}:{e}",
                  file=sys.stderr)
    if os.path.isdir("results/dryrun"):
        for n, us, d in _roofline_rows():
            print(f"{n},{us:.2f},{d}")


if __name__ == '__main__':
    main()
