#!/usr/bin/env python
"""Generate docs/FABRIC.md from a live multi-kernel fabric run.

Usage (see Makefile `docs` / `docs-check`):
    PYTHONPATH=src python scripts/gen_fabric_md.py > docs/FABRIC.md

The scheduler transcript, contention table, pricing-symmetry check and
fleet-DSE frontier below come from real runs, so the document can never
drift from the code without CI noticing.
"""

import dataclasses
import sys

from repro.core import fabric
from repro.core.fabric import TrafficMix, explore_fleet, fabric_stream, \
    make_fleet, saturating_cycles_per_unit, transaction_cost
from repro.core.host_bridge import AXI4, AXI4_LITE, Crossbar

#: deliberately starved interconnect so the contention table has a
#: genuinely DMA-bound row (device cycles no longer hide the wire)
NARROW8 = Crossbar("narrow8", data_width_bits=8, latency_cycles=8)
from repro.core.machine_model import TPU_V5E
from repro.core.pipeline import compile_gemm


def _fab_and_stream(ck, copies=2, requests=8, crossbar=AXI4,
                    policy="round_robin", name="gemm8"):
    fab = make_fleet({name: (ck.hw_module, ck.kernel)},
                     copies={name: copies}, crossbar=crossbar,
                     policy=policy)
    mix = TrafficMix("steady", ((name, 1.0),), num_requests=requests,
                     process="poisson", rate=1.0, seed=0)
    service = transaction_cost(ck.hw_module, crossbar,
                               ck.cycles.total).total
    mix = dataclasses.replace(mix, cycles_per_unit=saturating_cycles_per_unit(
        mix, service, load_factor=2.0 * copies))
    return fab, fabric_stream(mix), mix


def transcript_section(ck):
    fab, stream, _ = _fab_and_stream(ck, copies=2, requests=5)
    rep = fab.model(stream, overlap=True, transcript=True)
    lines = rep.transcript
    shown = lines[:48]
    out = ["```"]
    out += shown
    if len(lines) > len(shown):
        out.append(f"... ({len(lines) - len(shown)} more events)")
    out += ["```", "", "```", rep.summary(), "```"]
    return out


def contention_table(ck, ck_mxu):
    rows = ["| schedule | crossbar | dispatch | policy | makespan (cyc) | "
            "req/s | xbar util | speedup |",
            "|----------|----------|----------|--------|----------------|"
            "-------|-----------|---------|"]
    cases = [("nested", ck, AXI4), ("nested", ck, AXI4_LITE),
             ("tpu_mxu", ck_mxu, NARROW8)]
    for sched, k, xbar in cases:
        fab, stream, _ = _fab_and_stream(k, copies=3, requests=24,
                                         crossbar=xbar)
        ser = fab.model(stream, overlap=False)
        for label, rep in (
                ("serialized", ser),
                ("overlap", fab.model(stream, overlap=True)),
                ("overlap", dataclasses.replace(fab, policy="priority")
                 .model(stream, overlap=True))):
            rows.append(
                f"| {sched} | {xbar.name} | {label} | {rep.policy} | "
                f"{rep.total_cycles:,} | {rep.requests_per_s:,.0f} | "
                f"{rep.crossbar_utilization:.1%} | "
                f"{rep.requests_per_s / ser.requests_per_s:.2f}x |")
    return rows


def symmetry_section(ck):
    fab, stream, _ = _fab_and_stream(ck, copies=2, requests=8)
    ovl = fab.model(stream, overlap=True)
    sim = fab.simulate(stream, overlap=True)
    dev = (100.0 * abs(sim.requests_per_s - ovl.requests_per_s)
           / ovl.requests_per_s)
    return [
        f"* machine model:    **{ovl.requests_per_s:,.1f} req/s** "
        f"({ovl.total_cycles:,} cycles makespan)",
        f"* event simulator:  **{sim.requests_per_s:,.1f} req/s** "
        f"({sim.total_cycles:,} cycles, outputs checked against the "
        f"numpy oracle, max|err|={sim.max_abs_err:.1e})",
        f"* deviation: **{dev:.2f}%** (gate: ±10%)",
    ]


def fleet_section(ck):
    _, _, mix = _fab_and_stream(ck, copies=2, requests=8)
    res = explore_fleet({"gemm8": ck.graph}, mix, per_kernel=3,
                        max_copies=2, validate_top=2)
    rows = ["| fleet | area | req/s (model) | speedup vs serialized |",
            "|-------|------|---------------|-----------------------|"]
    for c in res.frontier:
        rows.append(f"| `{c.spec()}` | {c.area:,} | {c.model_rps:,.0f} | "
                    f"{c.speedup:.2f}x |")
    rows.append("")
    for v in res.validations:
        rows.append(f"* `{v.candidate.spec()}`: simulated "
                    f"{v.sim_rps:,.0f} req/s vs modeled "
                    f"{v.model_rps:,.0f} — deviation "
                    f"{v.deviation_pct:.2f}% "
                    f"({'ok' if v.ok else 'FAIL'})")
    return rows


def main(out=sys.stdout):
    w = lambda s="": print(s, file=out)
    ck = compile_gemm(8, 8, 8, schedule="nested",
                      want_jax=False, want_pallas=False)
    ck_mxu = compile_gemm(8, 8, 8, schedule="tpu_mxu",
                          want_jax=False, want_pallas=False)
    w("# Multi-kernel fabric — contention-aware crossbar scheduling")
    w()
    w("<!-- GENERATED FILE — do not edit by hand. -->")
    w("<!-- Regenerate with:")
    w("       PYTHONPATH=src python scripts/gen_fabric_md.py "
      "> docs/FABRIC.md")
    w("     (or `make docs`).  CI fails if this file is out of sync. -->")
    w()
    w("`src/repro/core/fabric.py` schedules a *fleet* of generated "
      "accelerators — N")
    w("`HwModule`s, each with its own CSR block and DMA queue — behind "
      "one shared")
    w("vendor crossbar.  A request stream (the `serve/loadgen.py` "
      "arrival processes,")
    w("scaled to device cycles by a `TrafficMix`) is dispatched across "
      "slots; each")
    w("request runs the full host transaction — CSR setup, DMA in, "
      "start, device")
    w("compute, done-polling, DMA out — priced term-for-term like "
      "`host_bridge.run_transaction`.")
    w()
    w("The win is **overlap**: DMA phases contend on the crossbar "
      "(round-robin is")
    w("modeled as processor sharing — n active bursts each progress "
      "1/n beats per")
    w("cycle, the per-beat arbitration limit; `priority` strictly "
      "preempts, lowest")
    w("value first), but one kernel's DMA proceeds while another "
      "computes.  The")
    w("serialized baseline is the same engine with a global "
      "one-transaction lock and")
    w("FIFO admission — exactly back-to-back `run_transaction` calls "
      "(pinned by test:")
    w("a one-slot, one-request fabric prices *identically* to "
      "`run_transaction`).")
    w()
    w("## A scheduled run, live")
    w()
    w("Two copies of the nested-schedule 8×8×8 GEMM behind AXI4, fed a "
      "saturating")
    w("Poisson stream (5 requests shown):")
    w()
    for line in transcript_section(ck):
        w(line)
    w()
    w("## Contention, honestly")
    w()
    w("Three copies, 24 requests, offered load ~2× fleet capacity.  The "
      "nested-schedule")
    w("GEMM is device-bound (≈10k device cycles vs ≈800 DMA beats), so "
      "overlap recovers")
    w("nearly the full slot count on any crossbar.  Swap in the "
      "`tpu_mxu` schedule —")
    w("same bytes, two-orders-of-magnitude fewer device cycles — on a "
      "deliberately")
    w("starved 8-bit crossbar and the fabric becomes DMA-bound: the "
      "crossbar saturates,")
    w("no arbitration policy can beat the shared-wire limit, and the "
      "speedup honestly")
    w("collapses toward 1×:")
    w()
    for row in contention_table(ck, ck_mxu):
        w(row)
    w()
    w("## Pricing symmetry (the PR-9 pattern, one level up)")
    w()
    w("`Fabric.model` and `Fabric.simulate` share ONE scheduling core "
      "(`Fabric._schedule`)")
    w("fed by two device-cycle sources: the analytic "
      "`machine_model.cycles` total, or")
    w("the *observed* cycle count from `hw_sim.simulate` (which also "
      "checks outputs")
    w("against the LoopIR numpy oracle).  Same stream, same fleet:")
    w()
    for line in symmetry_section(ck):
        w(line)
    w()
    w("## Fleet-level DSE")
    w()
    w("`explore_fleet` (also `CompiledKernel.explore_fleet` and "
      "`dse.explore_fleet`)")
    w("crosses each kernel's single-kernel DSE frontier with a copy "
      "count, prices")
    w("every feasible fleet against the traffic mix under a shared "
      "`ResourceBudget`,")
    w("and keeps the requests/s × total-area Pareto frontier; the top "
      "points are")
    w("re-validated by the event simulator (gate: ±10%):")
    w()
    for row in fleet_section(ck):
        w(row)
    w()
    w("`benchmarks/fabric_bench.py` records the full trajectory "
      "(`BENCH_fabric.json`,")
    w("schema `fabric_bench/v1`): ≥2 traffic mixes × ≥2 fleet configs, "
      "each overlap")
    w("schedule ≥1.3× its serialized baseline, every frontier point "
      "sim-validated.")
    w("`scripts/check_bench.py` (`make bench-check`) gates all "
      "committed BENCH files;")
    w("CI's `fabric-smoke` job byte-diffs two smoke runs and asserts "
      "the speedup floor.")


if __name__ == "__main__":
    main()
