#!/usr/bin/env python
"""Generate docs/SHARING.md from a live outlining + sharing run.

Usage (see Makefile `docs` / `docs-check`):
    PYTHONPATH=src python scripts/gen_sharing_md.py > docs/SHARING.md

The transcript, pattern statistics and area tables below come from
real pipeline runs, so the document can never drift from the code
without CI noticing.
"""

import sys

from repro.core import dse, frontend as fe, hw_ir, ir_text, machine_model
from repro.core.machine_model import TPU_V5E
from repro.core.passes import PassManager
from repro.core.pipeline import compile_gemm
from repro.core.rewrite import canonicalize
from repro.core.sharing import set_sharing


def _mlp_module():
    """Two identical matmul+relu layers, tiled — the repeated
    subcircuit the outliner folds."""

    def mlp(x, w1, w2):
        return fe.relu(fe.matmul(fe.relu(fe.matmul(x, w1)), w2))

    g = fe.trace(mlp, [fe.spec((8, 8))] * 3, name="mlp2")
    k = PassManager.parse(
        "lower{tile_m=4,tile_n=4,tile_k=4}").run(g).artifact
    return hw_ir.lower_to_hw(k)


def _row(name, mode, mod):
    cyc = machine_model.cycles(mod, TPU_V5E)
    return (f"| {name} | {mode} | {dse.area(mod)} | {mod.total_lanes()} | "
            f"{mod.register_bits()} | {mod.mux_bits()} | "
            f"{mod.shared_unit_count()} | {len(mod.submodules)} | "
            f"{mod.fsm_state_count()} | {cyc.total} |")


def _clone(mod):
    return ir_text.parse_hw_module(ir_text.print_hw_module(mod))


def area_table():
    rows = ["| subject | mode | area | Σlanes | reg bits | mux bits | "
            "shared | sub-defs | FSM states | cycles |",
            "|---------|------|------|--------|----------|----------|"
            "--------|----------|------------|--------|"]
    subjects = []
    ck = compile_gemm(8, 8, 8, schedule="inner_flattened",
                      want_jax=False, want_pallas=False)
    subjects.append(("gemm8/inner_flattened", ck.hw_module))
    subjects.append(("mlp2 (2 layers)", _mlp_module()))
    for name, mod in subjects:
        base = _clone(mod)
        canonicalize(base)
        rows.append(_row(name, "none", base))
        for mode in ("share", "serialize"):
            m = _clone(base)
            set_sharing(m, mode)
            rows.append(_row(name, mode, m))
    return rows


def transcript():
    out = []
    mod = _mlp_module()
    before = ir_text.print_ir(mod)
    res = PassManager.parse("outline-subcircuits,share-units").run(mod)
    stats = "; ".join(
        f"`{r.name}`: "
        + ir_text.format_pattern_stats(r.pattern_stats)
        for r in res.records)
    after = ir_text.print_ir(res.artifact)

    out.append("A two-layer MLP (`relu(relu(x@w1)@w2)`) tiled 4×4×4 "
               "lowers to a flat module whose two layers are "
               "structurally identical nests — and, uncanonicalized, "
               "one datapath unit per statement:")
    out += ["", "```", before, "```", ""]
    out.append(f"Running `outline-subcircuits,share-units` ({stats}):")
    out += ["", "```", after, "```"]
    return out


def main(out=sys.stdout):
    w = lambda s="": print(s, file=out)
    w("# Hierarchical HwIR — subcircuit outlining and time-multiplexed "
      "resource sharing")
    w()
    w("<!-- GENERATED FILE — do not edit by hand. -->")
    w("<!-- Regenerate with:")
    w("       PYTHONPATH=src python scripts/gen_sharing_md.py "
      "> docs/SHARING.md")
    w("     (or `make docs`).  CI fails if this file is out of sync. -->")
    w()
    w("Flat HwIR pays for every datapath unit it declares, even when "
      "two FSM states could")
    w("take turns on one adder — and it re-states a repeated subcircuit "
      "at every use site.")
    w("`src/repro/core/sharing.py` adds the two classic remedies as "
      "rewrites on the standard")
    w("driver, and the whole stack (verifier, pricing, simulator, "
      "text format, Verilog")
    w("emitter, DSE) understands the result.")
    w()
    w("## The hierarchical form")
    w()
    w("* **Sub-modules + instances** — `HwModule.submodules` holds "
      "child module definitions;")
    w("  an `inst @sub(operands...)` ctrl step calls one, binding each "
      "operand to the")
    w("  definition's ports by position (the simulator passes numpy "
      "*views*, so writes land")
    w("  in parent storage; both the model and the simulator charge "
      "`call_overhead_cycles`")
    w("  per invocation).  `emit-verilog` emits each definition once "
      "plus real instantiation")
    w("  lines.")
    w("* **Binding table** — `bind VIRT -> PHYS serial=S copies=C` rows "
      "map *virtual* unit")
    w("  names (what ctrl steps reference) onto *physical* declared "
      "units.  `serial > 1`")
    w("  means a wide virtual unit runs on narrower hardware in `S` "
      "beats; the model and")
    w("  simulator charge the identical stall formula "
      "(`seq_loop_overhead_cycles * (S-1) /")
    w("  copies` per dynamic use), so cosim stays symmetric by "
      "construction.")
    w()
    w("## The passes")
    w()
    w("| pass | what it does |")
    w("|------|--------------|")
    w("| `outline-subcircuits` | hashes the canonical textual form of "
      "every ctrl subtree (storages/units/counters anonymized), and "
      "outlines each shape that repeats into one sub-module definition "
      "instanced at every occurrence. |")
    w("| `share-units` | port-conflict-aware binding scheduler: one FSM "
      "state is active per cycle, so units used by *distinct* steps "
      "never conflict — same-kind units fold onto one physical unit "
      "behind an input mux (`max_copies=0`, pure sharing at "
      "`serial=1`), or additionally serialize wide units onto narrow "
      "hardware (`max_copies=1`). |")
    w("| `set-sharing` | the DSE knob: `mode=none|share|serialize` runs "
      "the two passes with the matching scheduler policy. |")
    w()
    w("`canonicalize` prunes orphaned unit declarations, dangling "
      "binding rows and")
    w("un-instanced sub-module definitions under their own stats "
      "(`prune-unused-unit`,")
    w("`prune-unused-module`) — never silently.  `dedupe-units` "
      "refuses to touch a unit")
    w("with a binding row, so serialization accounting survives "
      "canonicalization.")
    w()
    w("## What it costs, honestly")
    w()
    w("`dse.area` prices the hierarchical form: **summed** lanes over "
      "every declared unit")
    w("(sub-module definitions count once however many call sites "
      "instance them), register")
    w("bits, block RAM, stream double buffers, plus **mux overhead** "
      "per extra binding on a")
    w("shared unit.  Serialization shows up in `cycles` — smaller area "
      "is not free:")
    w()
    for row in area_table():
        w(row)
    w()
    w("Pure sharing (`share`) never grows area.  Outlining can: a "
      "sub-module definition is")
    w("separate hardware, so its units are no longer time-shared with "
      "the parent's pool —")
    w("the MLP rows above trade datapath lanes for a smaller FSM and a "
      "single statement of")
    w("each repeated layer.  `benchmarks/area_bench.py` records both "
      "directions in")
    w("`BENCH_area.json`, cosim-gated.")
    w()
    w("## An outlining + sharing run, live")
    w()
    for line in transcript():
        w(line)
    w()
    w("Every shared or serialized module above still co-simulates "
      "against the LoopIR numpy")
    w("oracle at `atol=1e-5` with observed cycles within ±10% of the "
      "model (the `simulate`")
    w("gate), and the printed form round-trips through "
      "`ir_text.parse_hw_module` at fixpoint.")


if __name__ == "__main__":
    main()
