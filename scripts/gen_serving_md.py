#!/usr/bin/env python
"""Generate docs/SERVING.md — the serving-under-load subsystem guide.

Every transcript is produced by actually running the load generator,
the batched continuous engine and the per-block compile plan in-process
under a virtual clock, so the document cannot drift from the runtime's
real behaviour: CI regenerates it and fails on any diff (same contract
as docs/RAISING.md / docs/DSE.md / docs/REWRITE.md).

    PYTHONPATH=src python scripts/gen_serving_md.py > docs/SERVING.md
    # or: make docs
"""

from __future__ import annotations

import importlib.util
import json
import os
import sys

from repro.serve import loadgen
from repro.serve.compiled import plan_blocks

_BENCH = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                      "serve_bench.py")


def _load_bench():
    spec = importlib.util.spec_from_file_location("serve_bench", _BENCH)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def loadgen_transcript() -> str:
    cfg = loadgen.LoadConfig(
        num_requests=6, vocab_size=151936, seed=0, process="bursty",
        rate=4.0, burst_rate=32.0,
        prompt=loadgen.LengthDist("uniform", 4, 10),
        output=loadgen.LengthDist("uniform", 2, 6))
    stream = loadgen.generate_stream(cfg)
    lines = [f"// {cfg.describe()}"]
    for r in stream:
        lines.append(f"// rid={r.rid} arrival={r.arrival:.3f}s "
                     f"prompt_len={len(r.prompt)} max_new={r.max_new}")
    lines.append(f"// digest={loadgen.stream_digest(stream)}")
    return "\n".join(lines)


def serve_transcript(bench) -> str:
    entry = bench.run_config(
        "qwen2_7b", slots=2, requests=8, rate=6.0, process="poisson",
        seed=0, clock_kind="virtual", queue_limit=4, prompt_hi=7,
        out_hi=5, with_plan=False, max_len=32)
    snap = json.dumps(entry["metrics"], indent=2, sort_keys=True)
    return bench.fmt_entry(entry) + "\n" + snap


def plan_transcript() -> str:
    return plan_blocks("qwen2_7b").describe()


def main() -> int:
    bench = _load_bench()
    lg = loadgen_transcript()
    serve = serve_transcript(bench)
    plan = plan_transcript()

    print(f"""\
# Serving under load

<!-- GENERATED FILE — do not edit.  Regenerate with `make docs`
     (scripts/gen_serving_md.py); CI diffs this against live output. -->

The paper measures its compiler by what the generated designs do under
real workloads; this repo's equivalent is `repro.serve`: a
serving-under-load subsystem that drives the model registry's reduced
configs with deterministic request streams, batches decode across
requests in ONE jit'd step, and records tail latency into the repo's
perf trajectory (`BENCH_serve.json`).

Four layers, each usable alone:

| module | role |
|---|---|
| `repro.serve.loadgen` | replayable workload generator: Poisson/bursty/uniform arrivals, configurable prompt/output length distributions |
| `repro.serve.continuous` | the batched continuous engine: slot-stacked caches, one vmap'd decode step, async admission queue with backpressure |
| `repro.serve.metrics` | per-request TTFT / TPOT / e2e in streaming log-bucket histograms, queue depth and slot occupancy per step |
| `repro.serve.compiled` | the compiler bridge: per-block compile plan (autotuned schedules, validated, explicit fallbacks) |

`repro.serve.engine` keeps the plain batched `Engine` (prefill +
decode over a fixed batch, EOS rows frozen to `eos_id`) and
`SerialSlotEngine`, the original per-slot B=1 continuous loop retained
as the bit-exact differential reference for the batched engine
(`tests/test_continuous_batching.py` asserts identical greedy token
streams on mixed request sets, including `max_new=1`).

## The load generator

A stream is a pure function of its `LoadConfig` — same seed, same
stream, byte for byte (`stream_digest` fingerprints it).  Bursty
arrivals are a two-state MMPP: a base-rate phase and a burst-rate
phase, so queueing behaviour under bursts is reproducible.

```
{lg}
```

## The batched continuous engine

`ContinuousEngine` holds ONE stacked cache pytree: each slot's rows are
exactly `model.cache_init(1, max_len)` stacked on a leading slot axis,
so per-slot scalar cache lengths survive and every slot decodes
identically to a B=1 engine — `jax.vmap` over the slot axis turns the
old per-slot Python loop (``slots`` XLA dispatches per token) into one
jit'd dispatch per token for the whole batch.  Admission prefills a
request at B=1, samples its first token, and writes the prefilled cache
into a free slot's rows with `dynamic_update_index_in_dim`; an
active-slot mask freezes empty slots.  `submit()` enqueues (with
backpressure once `queue_limit` is hit), `step()` admits + decodes one
token for every occupied slot, `drain()` runs to completion.

A request with `max_new=1` finishes at admission — the prefill already
sampled its only token, so it never occupies a slot (the off-by-one the
serial engine used to have).

## Latency metrics

`ServeMetrics` hooks the request lifecycle (submit -> admit -> first
token -> per-token -> finish) into `StreamingHistogram`s: log-spaced
buckets at 2% growth, so p50/p90/p99 are recovered within ~2% at O(1)
memory.  TTFT is measured from *arrival* (queueing included), TPOT is
the gap between consecutive decode tokens, queue depth and slot
occupancy are sampled once per engine step.  Time comes from a `Clock`:
`WallClock` for real runs, `VirtualClock` (each step advances a fixed
virtual cost) for byte-reproducible transcripts like this one:

```
{serve}
```

## The compiler bridge

`plan_blocks(config)` raises every forward-pass block
(`repro.core.raise`), compiles each raisable one through the
PassManager stack under the autotuner's schedule for its dominant
matmul shape (falling back through `tpu_mxu` to the always-legal
nested schedule), validates against the traced reference on real
inputs, and records explicit plain-jit fallbacks with reasons — a
`BENCH_serve.json` entry always states exactly which blocks of the
serving model ran through the compiler:

```
{plan}
```

## The recorded trajectory: BENCH_serve.json

`benchmarks/serve_bench.py` drives sustained mixed prefill/decode load
over ≥2 reduced configs and writes `BENCH_serve.json`
(schema `serve_bench/v1`): per config/workload, tokens/sec, p50/p90/p99
TTFT and TPOT, e2e latency, queue depth, slot utilization, requests
completed, plus the embedded compile plan.  `check_bench` is the CI
schema gate (`serve-smoke` job).

```sh
PYTHONPATH=src python benchmarks/serve_bench.py                 # 2 configs
PYTHONPATH=src python benchmarks/serve_bench.py --smoke         # CI seconds
PYTHONPATH=src python benchmarks/serve_bench.py --clock virtual # replayable
PYTHONPATH=src python benchmarks/serve_bench.py --mesh data=2   # sharded
```

## API

```python
from repro.serve import loadgen
from repro.serve.continuous import ContinuousEngine, Request
from repro.serve.metrics import ServeMetrics, WallClock

stream = loadgen.generate_stream(loadgen.LoadConfig(num_requests=32))
metrics = ServeMetrics(WallClock(), slots=4)
engine = ContinuousEngine(model, params, slots=4, max_len=256,
                          queue_limit=16, metrics=metrics)
for r in stream:
    while not engine.submit(Request(r.rid, r.prompt, r.max_new)):
        engine.step()                       # backpressure
engine.drain()
print(metrics.snapshot())                   # the BENCH_serve payload
```

Or from the launcher:

```sh
PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \\
    --continuous --slots 4 --requests 16 --rate 4
```""")
    return 0


if __name__ == "__main__":
    sys.exit(main())
