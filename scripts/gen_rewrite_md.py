#!/usr/bin/env python
"""Generate docs/REWRITE.md from the live rewrite-core registries.

Usage (see Makefile `docs` / `docs-check`):
    PYTHONPATH=src python scripts/gen_rewrite_md.py > docs/REWRITE.md

Everything below is produced from the actual pattern registries and a
real canonicalization run, so the document can never drift from the
code without CI noticing.
"""

import io
import sys

from repro.core import hw_ir, ir_text, rewrite
from repro.core.passes import PASS_REGISTRY, PassManager
from repro.core.reproc import quickstart_gemm
from repro.core.rewrite import CANONICAL_PATTERNS


def canonical_pattern_table(level: str) -> list:
    rows = ["| pattern | benefit | what it does |",
            "|---------|---------|--------------|"]
    for p in CANONICAL_PATTERNS[level]:
        rows.append(f"| `{p.name}` | {p.benefit} | {p.describe()} |")
    return rows


def ported_pass_table() -> list:
    rows = ["| pass | level | pattern set |",
            "|------|-------|-------------|"]
    for pd in sorted(PASS_REGISTRY.values(), key=lambda pd: pd.name):
        if not pd.patterns or pd.name == "canonicalize":
            continue
        pats = ", ".join(f"`{p}`" for p in pd.patterns)
        rows.append(f"| `{pd.name}` | {pd.level_str} | {pats} |")
    return rows


def live_transcript() -> list:
    """Canonicalize the quickstart GEMM at loop and hw level, full-dim
    tiles (the degenerate spelling the patterns exist for)."""
    g = quickstart_gemm(8, 8, 8)
    pipe = "lower{tile_m=8,tile_n=8,tile_k=8}"
    kernel = PassManager.parse(pipe).run(g).artifact
    before_loop = ir_text.print_ir(kernel)
    res = PassManager.parse("canonicalize").run(kernel)
    after_loop = ir_text.print_ir(res.artifact)
    loop_stats = ir_text.format_pattern_stats(res.records[0].pattern_stats)

    hw_before = hw_ir.lower_to_hw(
        PassManager.parse(pipe).run(quickstart_gemm(8, 8, 8)).artifact)
    before_hw = ir_text.print_ir(hw_before)
    hres = PassManager.parse("canonicalize").run(hw_before)
    after_hw = ir_text.print_ir(hres.artifact)
    hw_stats = ir_text.format_pattern_stats(hres.records[0].pattern_stats)

    out = []
    out.append("The quickstart GEMM lowered with full-dimension tiles "
               "(`reproc --gemm 8x8x8 --pipeline "
               "\"lower{tile_m=8,tile_n=8,tile_k=8},canonicalize\"`) is the "
               "degenerate spelling these patterns exist for — every loop "
               "has extent 1:")
    out.append("")
    out.append("```")
    out.append(before_loop)
    out.append("```")
    out.append("")
    out.append(f"`canonicalize` at loop level ({loop_stats}):")
    out.append("")
    out.append("```")
    out.append(after_loop)
    out.append("```")
    out.append("")
    out.append("Lowering the *uncanonicalized* kernel to hardware instead "
               "(`lower-to-hw`) gives trip-1 sequencers and one datapath "
               "unit per statement:")
    out.append("")
    out.append("```")
    out.append(before_hw)
    out.append("```")
    out.append("")
    out.append(f"`canonicalize` at hw level ({hw_stats}):")
    out.append("")
    out.append("```")
    out.append(after_hw)
    out.append("```")
    return out


def main(out=sys.stdout):
    w = lambda s="": print(s, file=out)
    w("# The rewrite core — one walk/rewrite/canonicalize "
      "infrastructure for all three IRs")
    w()
    w("<!-- GENERATED FILE — do not edit by hand. -->")
    w("<!-- Regenerate with:")
    w("       PYTHONPATH=src python scripts/gen_rewrite_md.py "
      "> docs/REWRITE.md")
    w("     (or `make docs`).  CI fails if this file is out of sync. -->")
    w()
    w("`src/repro/core/rewrite.py` is the stack's MLIR-pattern-rewrite "
      "analogue: instead of")
    w("every transform hand-rolling its own traversal and "
      "reconstruction, TensorIR, LoopIR")
    w("and HwIR all implement one small structural protocol and share "
      "one greedy fixpoint")
    w("driver.")
    w()
    w("## The structural protocol")
    w()
    w("| method | contract |")
    w("|--------|----------|")
    w("| `children()` | the node's *mutable* child list — `Graph.ops`, "
      "`Kernel.body`, `Loop.body`, `HwModule.ctrl`, `HwLoop.body`; "
      "leaves return `[]`.  The driver splices replacements into this "
      "list in place. |")
    w("| `rebuild(children)` | a same-type copy carrying a new child "
      "list (the functional counterpart). |")
    w("| `is_equivalent(other)` | structural equivalence via the "
      "canonical textual form (`ir_text`): two nodes are equivalent iff "
      "they print identically. |")
    w()
    w("## Patterns and the driver")
    w()
    w("A `Pattern` implements `match_and_rewrite(parent, siblings, i, "
      "root)` and returns")
    w("`None` (no match / already canonical) or `(consumed, "
      "replacement)`.  `benefit` orders")
    w("competing patterns.  `RewriteDriver(patterns).run(root)` sweeps "
      "the tree post-order")
    w("until a full sweep changes nothing (or the iteration cap trips), "
      "returning per-pattern")
    w("hit counts; the `PassManager` collects those counts onto each "
      "pass's `PassRecord`")
    w("(`reproc --timing` and `--dump-after-each` print them).")
    w()
    w("## Canonicalization pattern sets")
    w()
    w("`canonicalize` is registered at **tensor, loop and hw** level — "
      "the one pass that runs")
    w("on any IR artifact.  Its per-level pattern sets (extensible via "
      "`register_canonical_pattern(level)`):")
    for level, title in (("tensor", "TensorIR"), ("loop", "LoopIR"),
                         ("hw", "HwIR")):
        w()
        w(f"### {title}")
        w()
        for row in canonical_pattern_table(level):
            w(row)
    w()
    w("## Ported passes")
    w()
    w("The pre-existing schedule transforms and the HwIR sequencer knob "
      "now run as patterns")
    w("on the same driver (same pass names, same pipeline specs, "
      "cosim-verified semantics):")
    w()
    for row in ported_pass_table():
        w(row)
    w()
    w("The DSE also uses the canonical form: design points whose "
      "canonicalized kernels")
    w("coincide are spellings of one design, and `dse.explore` dedupes "
      "them before pricing")
    w("(every elimination is logged in the result table — no silent "
      "shrinkage).")
    w()
    w("## A canonicalization, live")
    w()
    for line in live_transcript():
        w(line)


if __name__ == "__main__":
    main()
