#!/usr/bin/env python
"""Generate docs/DSE.md — the design-space exploration guide.

Every transcript below is produced by actually running the ``reproc``
driver (or ``dse.explore``) in-process, so the document cannot drift
from the compiler's real output: CI regenerates it and fails on any
diff (same contract as docs/PASSES.md and docs/LOWERING.md).

    PYTHONPATH=src python scripts/gen_dse_md.py > docs/DSE.md
    # or: make docs
"""

from __future__ import annotations

import io
import os
import sys
import tempfile

# a fresh cache dir keeps the "N cached" header deterministic (always 0)
os.environ["STAGECC_DSE_CACHE"] = tempfile.mkdtemp(prefix="stagecc-dse-doc-")

from repro.core import dse, reproc  # noqa: E402


def run_reproc(*argv: str) -> str:
    buf = io.StringIO()
    rc = reproc.main(list(argv), out=buf)
    if rc != 0:
        raise RuntimeError(f"reproc {' '.join(argv)} exited {rc}")
    return buf.getvalue().rstrip("\n")


def block(cmd_args: list, lang: str = "") -> str:
    shown = "PYTHONPATH=src python -m repro.core.reproc " + " ".join(cmd_args)
    out = run_reproc(*cmd_args)
    return (f"```sh\n{shown}\n```\n\n"
            f"```{lang}\n{out}\n```")


def main() -> int:
    table = block(["--gemm", "8x8x8", "--epilogue", "none", "--dse=4"])

    g = reproc.quickstart_gemm(8, 8, 8, epilogue="none")
    points = dse.enumerate_points(g)
    fam_rows = "\n".join(
        f"| `{pt.family}` | `{pt.spec}` |"
        for pt in points)

    print(f"""# DSE — design-space exploration over schedules × HwIR

<!-- GENERATED FILE — do not edit by hand. -->
<!-- Regenerate with:
       PYTHONPATH=src python scripts/gen_dse_md.py > docs/DSE.md
     (or `make docs`).  CI fails if this file is out of date: every
     transcript below is captured from the real `reproc` driver. -->

The paper's loop is manual: pick a transformation, generate RTL,
simulate it in Vivado, read cycles/utilisation off the reports, repeat.
`repro.core.dse` folds that loop into the compiler:

```
enumerate schedule programs ──► lower each through the real pipeline
  (pass-pipeline specs)          (PassManager → Kernel → HwModule)
        │                               │
        │                        price structurally
        │                        (machine_model.cycles / resources / area)
        ▼                               ▼
  on-disk candidate cache ◄──── cycles × area Pareto frontier
  (keyed: graph, machine,               │
   schedule program, budget)            ▼
                                 validate top-K by co-simulation
                                 (hw_sim.cosim vs the numpy oracle,
                                  observed vs modeled cycles)
```

A **design point** is a *schedule program*: a replayable pass-pipeline
spec over the LoopIR scheduling passes, plus an optional HwIR-level
knob pipeline applied after `lower-to-hw`.  Nothing about a point is
opaque — paste its `SCHEDULE PROGRAM` column into
`reproc --pipeline ...` to replay it.

## The search space

Families instantiated for the 8×8×8 GEMM (loop names, extents and
scratch buffers are discovered from the real nested lowering):

| family | schedule program |
|--------|------------------|
{fam_rows}

The two *paper* points are `nested` (time-multiplexed `@fsm` baseline)
and `inner_flattened` (the paper's §III unrolling).  Beyond them:

* `split_unroll` — partial spatial replication: `split{{var,factor}}`
  then `unroll` the inner loop ⇒ the datapath unit is replicated
  `factor`× (`HwUnit.copies`), trading area for removed control;
* `simd` — `vectorize` a loop onto VPU lanes.  Only generated where
  **legal**: every tile written under the loop must be indexed by the
  loop variable (GEMM's K loop is a reduction — unrollable, *not*
  vectorizable — so the pure GEMM has no `simd` points);
* `interchange` — swap a perfectly-nested pair (only enumerated when
  the extents differ, i.e. when it changes the trip structure);
* `vmem_acc` — memory-space placement: push the accumulator from
  `@vreg` into `@vmem` (fewer register bits, one BRAM block);
* `stream_outer` / `flat_stream` — the HwIR-level knob: re-sequence the
  outer `@fsm` loop as `@stream` (`set-sequencer`), buying the grid
  sequencer's double-buffered DMA overlap at the price of ping-pong
  buffer area;
* `tpu_mxu` / `tpu_mxu_kgrid` — the TPU-native grid-mapped MXU tilings
  (`fuse-epilogue` + `grid`), one point per tile edge.

## Pricing and the frontier

Each point lowers to a real `HwModule` and is priced structurally —
`machine_model.cycles` (FSM transitions, unit latencies, port traffic)
and `machine_model.resources`, folded into one scalar **area**
(`dse.area`): datapath lanes × {dse.LANE_AREA} FF/LUT-equivalents +
register bits + block-quantized BRAM bits (18Kb blocks, {dse.BRAM_BIT_DISCOUNT}×
denser than FFs) + `@stream` double-buffer RAM.  Feasibility is checked
against a `ResourceBudget` (the FPGA-size analogue; defaults derive
from the machine).  Candidates that survive land on the strict
cycles × area Pareto frontier, and the top-K frontier points are
**validated** exactly the way the paper validates RTL: `hw_sim.cosim`
executes the module cycle-accurately and checks outputs against the
numpy oracle and observed cycles against the model.

## The CLI

```sh
PYTHONPATH=src python -m repro.core.reproc --gemm 32x32x32 \\
    --epilogue none --dse --pareto-csv pareto.csv
```

{table}

Pricing results are memoized on disk — re-running reports
`(N cached)` and only new design points recompile.  The cache key is
(graph text, machine, schedule program, budget); set
`STAGECC_DSE_CACHE` to relocate it.

## The other entry points

* **library** — `dse.explore(graph, machine=..., validate_top=4)` →
  `DseResult` (`.frontier`, `.best()`, `.table()`, `.to_csv()`);
* **artifact** — `compile_gemm(...).explore(validate_top=4)` explores
  around a compiled kernel's graph on its machine;
* **pipeline** — the `dse` *pass*:
  `reproc --gemm 16x16x16 --pipeline "dse,lower-to-hw,emit-verilog"`
  searches, then keeps lowering the winning schedule;
* **benchmark** — `python -m benchmarks.pareto` prints the frontier
  CSV for the paper sizes plus an ASCII cycles×area scatter.

See also [ARCHITECTURE.md](ARCHITECTURE.md) (where DSE sits in the
stack), [PASSES.md](PASSES.md) (the `dse`, `set-space` and
`set-sequencer` passes), and `tests/test_dse.py` (the acceptance
contract: both paper points plus ≥3 new families on the 32³ frontier,
every frontier point co-simulating within 1e-5 of the oracle and ±10%
of its modeled cycles).""")
    return 0


if __name__ == "__main__":
    sys.exit(main())
