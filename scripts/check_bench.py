"""Unified BENCH_*.json validator — ``make bench-check``.

Every benchmark that commits a ``BENCH_*.json`` trajectory registers its
schema here, mapped to the benchmark module that owns the matching
``check_bench(doc)`` gate.  This script loads each committed file,
dispatches on its ``schema`` field, and fails loudly on: unknown
schemas, files that no checker claims, or any gate violation (e.g. a
fabric entry whose overlap speedup slipped below the 1.3x floor, or a
serve entry with a malformed latency histogram).

  PYTHONPATH=src python scripts/check_bench.py [FILES...]

With no arguments, validates every BENCH_*.json in the repo root.
"""

from __future__ import annotations

import importlib.util
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

#: schema tag -> benchmark module (relative to repo root) owning check_bench
REGISTRY = {
    "serve_bench/v1": "benchmarks/serve_bench.py",
    "area_bench/v1": "benchmarks/area_bench.py",
    "fabric_bench/v1": "benchmarks/fabric_bench.py",
}


def _load_checker(rel: str):
    path = ROOT / rel
    spec = importlib.util.spec_from_file_location(
        pathlib.Path(rel).stem, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod.check_bench


def check_file(path: pathlib.Path) -> str:
    doc = json.loads(path.read_text())
    schema = doc.get("schema")
    if schema not in REGISTRY:
        raise ValueError(
            f"{path.name}: schema {schema!r} not in the registry "
            f"({', '.join(sorted(REGISTRY))}) — register it in "
            f"scripts/check_bench.py")
    _load_checker(REGISTRY[schema])(doc)
    n = len(doc.get("entries", []))
    return f"{path.name}: {schema} ok ({n} entries)"


def main(argv=None) -> int:
    args = sys.argv[1:] if argv is None else argv
    paths = ([pathlib.Path(a) for a in args]
             if args else sorted(ROOT.glob("BENCH_*.json")))
    if not paths:
        print("check_bench: no BENCH_*.json files found", file=sys.stderr)
        return 1
    failed = False
    for p in paths:
        try:
            print(check_file(p))
        except Exception as exc:  # noqa: BLE001 - report every file
            print(f"{p.name}: FAIL: {exc}", file=sys.stderr)
            failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
