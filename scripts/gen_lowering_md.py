#!/usr/bin/env python
"""Generate docs/LOWERING.md — the end-to-end lowering tutorial.

Every IR dump in the tutorial is produced by actually running the
``reproc`` driver (or the models it feeds) in-process, so the document
cannot drift from the compiler's real output: CI regenerates it and
fails on any diff (same contract as docs/PASSES.md).

    PYTHONPATH=src python scripts/gen_lowering_md.py > docs/LOWERING.md
    # or: make docs
"""

from __future__ import annotations

import io
import sys

from repro.core import machine_model, reproc
from repro.core.pipeline import compile_gemm

#: the worked example — the paper's 4x4 scalar GEMM (TABLE I, first row)
GEMM = "4x4x4"
PAPER_NESTED, PAPER_FLAT = 1_498, 1_114


def run_reproc(*argv: str) -> str:
    """Run the reproc driver in-process and capture its stdout."""
    buf = io.StringIO()
    rc = reproc.main(list(argv), out=buf)
    if rc != 0:
        raise RuntimeError(f"reproc {' '.join(argv)} exited {rc}")
    return buf.getvalue().rstrip("\n")


def block(cmd_args: list, lang: str = "") -> str:
    shown = "PYTHONPATH=src python -m repro.core.reproc " + " ".join(cmd_args)
    out = run_reproc(*cmd_args)
    return (f"```sh\n{shown}\n```\n\n"
            f"```{lang}\n{out}\n```")


def main() -> int:
    g = ["--gemm", GEMM, "--epilogue", "none"]

    tensor = block(g)
    loop_nested = block(g + ["--pipeline", "lower"])
    loop_flat = block(g + ["--pipeline", "lower,flatten-inner"])
    hw = block(g + ["--pipeline", "lower,flatten-inner,lower-to-hw"])
    verilog = block(g + ["--pipeline", "lower,flatten-inner", "--emit",
                         "verilog"], lang="verilog")

    cosim = block(g + ["--pipeline", "lower", "--emit", "hw",
                       "--simulate", "host"])

    # the serving-kernel walkthrough: flash attention through the stack
    fl = ["--kernel", "flash:4x8x4"]
    t4 = "tile_m=4,tile_n=4,tile_k=4"
    flash_tensor = block(fl)
    flash_loop = block(fl + ["--pipeline", f"lower{{{t4}}}"])
    flash_sched = block(
        fl + ["--pipeline", f"lower{{{t4}}},fuse-epilogue,grid{{vars=2}}"])

    from repro.core import frontend as fe
    from repro.core.passes import PassError, run_pipeline
    try:
        run_pipeline(fe.ssd_scan_graph(8, 2, 4),
                     f"lower{{{t4}}},grid{{vars=2}}")
        raise RuntimeError("gridding the scan axis should have diagnosed")
    except PassError as e:
        ssd_diag = str(e)

    nested = compile_gemm(4, 4, 4, schedule="nested",
                          want_jax=False, want_pallas=False)
    flat = compile_gemm(4, 4, 4, schedule="inner_flattened",
                        want_jax=False, want_pallas=False)
    ncyc = machine_model.cycles(nested.hw_module)
    fcyc = machine_model.cycles(flat.hw_module)
    nres = machine_model.resources(nested.hw_module)
    fres = machine_model.resources(flat.hw_module)

    print(f"""# Lowering, end to end — one GEMM through every level

<!-- GENERATED FILE — do not edit by hand. -->
<!-- Regenerate with:
       PYTHONPATH=src python scripts/gen_lowering_md.py > docs/LOWERING.md
     (or `make docs`).  CI fails if this file is out of date: every IR
     dump below is captured from the real `reproc` driver. -->

This tutorial walks the paper's 4×4 GEMM case study (TABLE I, first
row) through all of stagecc's IR levels.  Each dump below is the exact
output of the shown command — run them yourself from the repo root.

The stack (the paper's Fig. 1, see [ARCHITECTURE.md](ARCHITECTURE.md)):

```
python (traced) → TensorIR → LoopIR → scheduled LoopIR → HwIR → Verilog-style RTL
                                                          ├→ structural cycles / resources
                                                          └→ HwSim: cycle-accurate execution
                                                              (+ host/crossbar co-simulation)
```

## Level 1 — TensorIR (the MLIR role)

The driver's built-in GEMM module, printed with no pipeline (`reproc`
acts as a round-trip printer, like `mlir-opt` with no passes):

{tensor}

## Level 2 — LoopIR (the Calyx role)

`lower` turns each tensor op into a *nested sequential* loop nest over
tiles — the paper's time-multiplexed baseline ("nested for-loop").
Control (`@seq` loops) and storage (`@hbm` / `@vreg` buffers) are now
explicit:

{loop_nested}

## Level 2, scheduled — the paper's §III transformation

`flatten-inner` is the paper's single studied optimisation: the
innermost loop is fully unrolled so its datapath is replicated
spatially (`@seq` → `@unrolled`, "Inner Flattened for-loop"):

{loop_flat}

## Level 3 — HwIR (the Calyx-to-RTL role)

`lower-to-hw` lowers the scheduled kernel to an FSM + datapath hardware
module: HBM params become memory **port**s, `@vreg` scratch becomes
**reg**ister banks, every leaf statement binds to a datapath **unit**
(`mac` scalar multiply-accumulate here; `mxu` for systolic tiles, `vpu`
for elementwise), and loops become hardware sequencers — `@fsm`
(time-multiplexed, one FSM transition per trip) or `@unroll` (spatially
replicated copies, note `x4` on the MAC unit):

{hw}

Like the two levels above it, HwIR has a canonical textual form:
`print(parse(print(hw)))` is a fixpoint (see `tests/test_hw_ir.py`).

## Level 4 — Verilog-style RTL (the paper's emission stage)

`emit-verilog` pretty-prints the module as RTL text — FSM state
encoding, loop counters, register banks, generate-replicated units.
(`--emit=verilog` is the shortcut that appends the default remaining
lowerings to whatever the pipeline produced; `--pipeline
"...,lower-to-hw,emit-verilog"` spells the same thing as passes.)

{verilog}

## Reading TABLE I / Fig. 3 off the hardware

`machine_model.cycles` / `resources` walk the HwIR structure — FSM
transitions per trip, unit latencies, memory-port traffic, register
bits, datapath lanes × copies — the quantities the paper reads off
Vivado for its generated RTL:

```python
from repro.core import machine_model
from repro.core.pipeline import compile_gemm

nested = compile_gemm(4, 4, 4, schedule="nested").hw_module
flat   = compile_gemm(4, 4, 4, schedule="inner_flattened").hw_module
machine_model.cycles(nested)     # {ncyc}
machine_model.cycles(flat)       # {fcyc}
machine_model.resources(nested)  # {nres}
machine_model.resources(flat)    # {fres}
```

Paper (TABLE I, 4×4): nested {PAPER_NESTED:,} cycles, inner-flattened
{PAPER_FLAT:,} cycles — a 1.34× gain for proportional hardware growth;
the structural model lands within 15% absolute with the same mechanism:
flattening removes the k-loop's FSM transitions (control
{ncyc.control} → {fcyc.control}) while compute stays port-limited
({ncyc.compute} cycles in both), and the datapath grows from
{nres.compute_lanes} to {fres.compute_lanes} MAC lanes
(`benchmarks/table1_cycles.py`, `benchmarks/fig3_resources.py`).

## Simulate it — the hardware level executes

Pricing a module is one half of the Vivado role; *running* it is the
other.  `--simulate` executes the hardware module cycle-accurately in
`hw_sim` (operand address generators resolve to real numpy slices, each
datapath invocation and FSM transition is charged its latency) and
co-simulates: outputs are checked against the LoopIR numpy oracle and
the **observed** cycle count lands next to the **modeled** one.
`--simulate host` additionally wraps the run in the paper's crossbar
integration — the host programs the generated CSR block, DMAs the input
buffers in, kicks `CTRL.start`, polls `STATUS.done`, and DMAs the
result back, with every phase priced in cycles:

{cosim}

The observed count matches the modeled one because both walk the same
hardware with the same unit latencies (`machine_model.step_cycles` is
the single source of truth) — a real divergence is a scheduling bug,
and the `simulate` *pass* (`--pipeline "...,lower-to-hw,simulate"`)
fails the pipeline on exactly that, or on non-finite outputs.  From
Python the same checks are one call:

```python
ck = compile_gemm(4, 4, 4, schedule="nested")
rep = ck.simulate(a, b)          # SimMismatch on numeric divergence
rep.observed_cycles, rep.modeled_cycles, rep.max_abs_err
tr = ck.simulate_host(a, b)      # full DMA/CSR/poll transaction
tr.total_cycles - tr.device_cycles   # the crossbar's toll
```

Add `--trace` for the per-state retired-event trace and `--vcd FILE`
for a waveform-style dump of the schedule
(`benchmarks/table1_cycles.py` reports modeled-vs-simulated columns for
every TABLE I size).

## The serving kernels — carried state through the same pipeline

GEMM's loops are embarrassingly tileable; the serving kernels are not.
Flash attention's online softmax carries a running max/sum across the
key axis, and the Mamba SSD scan carries its state across time — the
first structures in the stack where *which* loop a schedule may
parallelise is a legality question.  Both are plain TensorIR modules
(`--kernel flash|decode|ssd`), built with the carried `reduce` / `scan`
ops:

{flash_tensor}

`lower` gives each carried reduction the online-softmax shape: a `fill`
initialises the VREG statistic to the reduction identity (`-1e+30` for
max), a *sequential* carry loop threads it through `reduce<max,acc>`
steps, and a copy materialises the result — same pattern for `sum`,
and `scan<linear>` threads its carry row across the time loop:

{flash_loop}

Schedules apply unchanged — `fuse-epilogue` packs the elementwise tail
into the producer nest and `grid{{vars=2}}` maps the outer rows onto the
pallas grid — but the carry loops stay `@seq`.  A schedule that tried to
grid or vectorise a carry axis is refused with a diagnostic instead of
silently miscompiling (pinned in `tests/test_loop_ir_passes.py`):

```
$ PYTHONPATH=src python -m repro.core.reproc --kernel ssd:8x2x4 \\
      --pipeline "lower{{tile_m=4,tile_n=4,tile_k=4}},grid{{vars=2}}"
error: {ssd_diag}
```

{flash_sched}

From here the flow is identical to the GEMM's: `lower-to-hw` maps
`reduce`/`scan` steps onto VPU units (priced by the machine model,
executed bit-exactly by HwSim against the numpy oracle), and the
general pallas emitter turns every nest into a `pl.pallas_call` —
`tests/test_compiled_kernels.py` runs the full differential matrix
(compiled pallas vs the hand-written kernels in `repro/kernels/` vs
closed-form numpy) and `benchmarks/kernel_bench.py --compiled` writes
the wall-clock/cycles comparison to `BENCH_kernels.json`.

## Where to go next

* [ARCHITECTURE.md](ARCHITECTURE.md) — stage-by-stage map of the stack
* [PASSES.md](PASSES.md) — the (generated) pass reference
* `examples/quickstart.py` — the same flow driven from Python
* `examples/extend_pipeline.py` — registering new ops/passes from
  outside the core""")
    return 0


if __name__ == "__main__":
    sys.exit(main())
